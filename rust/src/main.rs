//! `repro` — the MOSS framework launcher.
//!
//! Subcommands map to the paper's workflows:
//!   train       pretrain on the synthetic corpus (Fig. 5 / Table 2)
//!   ablate      run all four numerics modes on the host backend and
//!               print the final-loss table (Fig. 5 / Table 2 in one
//!               command, zero artifacts)
//!   finetune    fine-tune on arithmetic-reasoning tasks (Fig. 6 / Table 3)
//!   eval        perplexity of a checkpoint over the three eval splits
//!   snr         Table-7 SNR study on random or probed activations
//!   gemm-table  Table-6 / Fig-1 GEMM cost-model tables
//!   comm-table  Table-5 memory & communication simulation; --predict
//!               replays the measured pipeline through a fitted netmodel
//!               at cluster shapes we can't run
//!   netmodel    least-squares fit the topology-aware alpha-beta network
//!               model from a measured --events comm_bucket stream
//!   scale-sim   Fig-4 scale-trajectory demo
//!   report      regenerate every table/figure into results/
//!   hlo-stats   artifact inventory + op statistics (L2 perf checks)
//!   events      summarize a --events JSONL telemetry stream offline;
//!               --trend renders the committed perf trajectory
//!   kernels     GEMM dispatch + autotuner-cache report; --require-simd
//!               is the CI guard against a silent scalar fallback
//!
//! `train`, `serve`, `ablate` and `comm-table` accept `--events PATH`:
//! every step emits a typed JSONL event (see `moss::events`) without
//! perturbing the run — the stream is observation-only and the step
//! stays bitwise-identical.

use std::sync::Arc;

use anyhow::{bail, Result};
use moss::backend::{DistTrainer, HostTrainer};
use moss::cli::{usage, Args};
use moss::config::{BackendKind, TrainConfig};
use moss::coordinator::Trainer;
use moss::events::{fnum, run_start, Event, EventSink};
use moss::runtime::Runtime;
use moss::util::json::{num, obj, s as jstr, Json};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const COMMANDS: &[(&str, &str)] = &[
    (
        "train",
        "pretrain on the synthetic corpus (--backend host|aot, \
         --model mlp|transformer, --heads N, --workers N, --nodes N, \
         --wire f32|fp8|packed, --overlap, --zero, --zero2, --accum K, \
         --bucket-mb MB, --mode bf16|pertensor|coat|moss, --steps, \
         --scaling, --events PATH)",
    ),
    (
        "ablate",
        "train all four --mode numerics on the host backend over one shared \
         seed/corpus and print the final-loss table (zero artifacts); \
         --sweep-interval [N,N,..] sweeps the MOSS re-anchor interval \
         against the bf16 anchor instead",
    ),
    (
        "netmodel",
        "fit the topology-aware alpha-beta network model from a measured \
         --events stream's comm_bucket records (repro netmodel --fit \
         EVENTS.jsonl [--world W] [--out fit.json])",
    ),
    (
        "serve",
        "FP8 serving engine: pack-once weights, KV-cache decode, continuous \
         batching over synthetic Poisson traffic (--ckpt PATH | --synthetic, \
         --requests N, --rate R, --max-batch B, --threads T, --max-ctx N, \
         --assert-throughput, --events PATH; emits BENCH_serve.json)",
    ),
    (
        "events",
        "summarize a JSONL telemetry stream (repro events PATH [--check]); \
         --trend renders bench/trajectory.jsonl as a perf-regression table \
         (--max-drop-pct N, default 20)",
    ),
    (
        "kernels",
        "report the GEMM kernel dispatch (detected ISA, SIMD on/off) and the \
         autotuner cache (--require-simd fails if the runtime probe fell back \
         to scalar — the CI guard against a silently-degraded build)",
    ),
    ("finetune", "fine-tune on math tasks and report accuracy"),
    ("eval", "perplexity of a checkpoint over wikitext/c4/pile splits"),
    ("snr", "Table-7 SNR study across quantization schemes"),
    ("gemm-table", "Table-6/Fig-1 H800 GEMM cost model"),
    (
        "comm-table",
        "Table-5 memory & communication simulation; --predict replays the \
         measured pipeline through a fitted netmodel at --world W --nodes N",
    ),
    ("scale-sim", "Fig-4 automatic-vs-JIT scale trajectories"),
    ("report", "regenerate all paper tables/figures into results/"),
    ("hlo-stats", "artifact inventory and HLO op statistics"),
];

fn run() -> Result<()> {
    let args = Args::from_env()?;
    if args.has("help") || args.subcommand.is_none() {
        print!("{}", usage("repro", COMMANDS));
        return Ok(());
    }
    match args.subcommand.as_deref().unwrap() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "kernels" => cmd_kernels(&args),
        "ablate" => moss::report::training::run_ablate_cli(&args),
        "finetune" => cmd_finetune(&args),
        "eval" => cmd_eval(&args),
        "snr" => moss::report::snr::run_cli(&args),
        "gemm-table" => moss::report::gemm::run_cli(&args),
        "comm-table" => moss::report::comm::run_cli(&args),
        "netmodel" => moss::report::comm::run_netmodel_cli(&args),
        "scale-sim" => moss::report::scaling::run_cli(&args),
        "report" => moss::report::run_all(&args),
        "hlo-stats" => moss::report::hlo_stats::run_cli(&args),
        "events" => moss::report::trend::run_cli(&args),
        other => bail!("unknown command {other:?}\n{}", usage("repro", COMMANDS)),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = TrainConfig::default().apply_args(args)?;
    if cfg.backend == BackendKind::Host {
        return cmd_train_host(args, cfg);
    }
    // the data-parallel machinery only exists on the host backend:
    // reject its flags rather than silently training single-worker
    for flag in
        ["workers", "wire", "shard", "overlap", "zero", "zero2", "bucket-mb", "nodes", "accum"]
    {
        if args.get(flag).is_some() || args.has(flag) {
            bail!("--{flag} requires --backend host (the AOT path has no simulated workers)");
        }
    }
    if args.get("events").is_some() {
        bail!("--events requires --backend host (the telemetry hooks live on the host backends)");
    }
    let rt = Arc::new(Runtime::load(&cfg.artifact_dir())?);
    eprintln!(
        "model: {} ({} params), mode {}, {} steps",
        rt.manifest.config_name,
        rt.manifest.model.param_count,
        cfg.mode.name(),
        cfg.steps
    );
    let steps = cfg.steps;
    let eval_every = cfg.eval_every;
    let mut trainer = Trainer::new(rt.clone(), cfg.clone())?;
    let mut remaining = steps;
    while remaining > 0 {
        let chunk = if eval_every > 0 { eval_every.min(remaining) } else { remaining };
        trainer.run(chunk)?;
        remaining -= chunk;
        if eval_every > 0 {
            for (split, ppl) in
                moss::eval::perplexity::eval_three_splits(&rt, &trainer.state, 4)?
            {
                eprintln!("  eval {split}: ppl {ppl:.2}");
            }
        }
    }
    let tail = trainer.history.tail_loss(20);
    println!(
        "done: {} steps, final loss {:.4}, {:.0} tokens/s (scaling: {} absmax calls)",
        trainer.state.step,
        tail,
        trainer.throughput.tokens_per_sec(),
        trainer.scaling_stats().absmax_calls,
    );
    if args.has("profile") {
        // §Perf L3 breakdown: where the coordinator's wall time goes.
        let wall = trainer.throughput.elapsed_secs();
        eprintln!("\n-- hot-path profile (wall {wall:.1}s) --");
        let mut total_exec = 0.0;
        let mut total_dl = 0.0;
        for (name, st) in rt.all_stats() {
            if st.calls == 0 {
                continue;
            }
            eprintln!(
                "  {name:<22} calls {:>5}  exec {:>8.2}s ({:>4.1}%)  download {:>6.2}s",
                st.calls,
                st.exec_secs,
                st.exec_secs / wall * 100.0,
                st.download_secs
            );
            total_exec += st.exec_secs;
            total_dl += st.download_secs;
        }
        eprintln!(
            "  coordinator overhead (data gen, marshalling, scaling, logging): {:.2}s ({:.1}%)",
            wall - total_exec - total_dl,
            (wall - total_exec - total_dl) / wall * 100.0
        );
    }
    if let Some(out) = &trainer.cfg.out_dir {
        std::fs::create_dir_all(out)?;
        std::fs::write(out.join("losses.csv"), trainer.history.losses_csv())?;
        moss::coordinator::checkpoint::save(&out.join("ckpt.bin"), &rt, &trainer.state)?;
        eprintln!("wrote {}/losses.csv and ckpt.bin", out.display());
    }
    Ok(())
}

/// `train --backend host`: the artifact-free host train loop under the
/// selected `--mode` numerics (bf16 reference, per-tensor FP8, COAT
/// per-group, or the MOSS two-level default). `--assert-improved`
/// turns "the loss went down and stayed finite" into the exit code —
/// the contract the `e2e-host-train` CI job gates per mode. With
/// `--workers N` (N > 1) the step runs data-parallel across N
/// simulated workers with a real gradient allreduce.
fn cmd_train_host(args: &Args, cfg: TrainConfig) -> Result<()> {
    let spec = cfg.host;
    if moss::backend::is_dist(&cfg) {
        return cmd_train_dist(args, cfg);
    }
    let steps = cfg.steps;
    let mut trainer = HostTrainer::new(cfg)?;
    let sink = EventSink::from_args(args)?;
    if sink.active() {
        sink.emit(&run_start("train", trainer.cfg.mode.name(), host_spec_json(&trainer.cfg)));
        trainer.set_sink(sink.clone());
    }
    eprintln!(
        "host backend: model {} ({} heads), mode {} ({}), vocab {} dim {} ffn {} layers {} \
         ({} params), {} steps x {} microbatches",
        spec.model.name(),
        spec.heads,
        trainer.cfg.mode.name(),
        if trainer.numerics.is_fp8() { "fp8" } else { "bf16 reference" },
        spec.vocab,
        spec.dim,
        spec.ffn,
        spec.layers,
        spec.param_count(),
        steps,
        spec.microbatches
    );
    trainer.run(steps)?;
    let first = trainer.history.losses.first().map_or(f64::NAN, |&(_, l)| l);
    let tail = trainer.history.tail_loss(10);
    let cache = trainer.cache.stats();
    println!(
        "done: {} steps, first loss {:.4}, final loss {:.4}, {:.0} tokens/s \
         (scaling {}: {} absmax calls; weight packs {}, cache hits {})",
        trainer.steps_done,
        first,
        tail,
        trainer.throughput.tokens_per_sec(),
        trainer.scaler_name(),
        trainer.scaling_stats().absmax_calls,
        cache.packs,
        cache.hits,
    );
    if sink.active() {
        sink.emit(&Event::RunEnd {
            summary: obj(vec![
                ("steps", num(trainer.steps_done as f64)),
                ("first_loss", fnum(first)),
                ("final_loss", fnum(tail)),
                ("tokens_per_sec", fnum(trainer.throughput.tokens_per_sec())),
                ("absmax_calls", num(trainer.scaling_stats().absmax_calls as f64)),
            ]),
        });
        let lines = sink.close()?;
        eprintln!("events: wrote {lines} lines to {}", args.get_or("events", "?"));
    }
    if let Some(out) = &trainer.cfg.out_dir {
        std::fs::create_dir_all(out)?;
        std::fs::write(out.join("losses.csv"), trainer.history.losses_csv())?;
        let ckpt = moss::coordinator::Checkpoint::from_model(
            &trainer.model,
            trainer.cfg.mode,
            trainer.steps_done,
        );
        ckpt.save(&out.join("ckpt.bin"))?;
        eprintln!("wrote {}/losses.csv and ckpt.bin (serve with --ckpt)", out.display());
    }
    if args.has("assert-improved") {
        if !first.is_finite() || !tail.is_finite() {
            bail!("non-finite loss: first {first}, final {tail}");
        }
        if tail >= first {
            bail!("loss did not decrease: first {first:.4} -> final {tail:.4}");
        }
        eprintln!("loss improved: {first:.4} -> {tail:.4}");
    }
    Ok(())
}

/// `repro serve`: the FP8 inference engine. Loads a self-describing
/// host checkpoint (`--ckpt`, zero re-specified shape/mode flags) or a
/// fresh seeded model (`--synthetic`, transformer by default), packs
/// every weight once, and drains an open-loop Poisson workload through
/// the continuous-batching scheduler. Always writes `BENCH_serve.json`;
/// `--assert-throughput` turns the packed-vs-dequantize decode gate and
/// full workload completion into the exit code (the `e2e-serve` CI
/// contract).
fn cmd_serve(args: &Args) -> Result<()> {
    use moss::backend::serve;
    use moss::backend::{DecodePath, Model};
    let serve_spec = moss::config::ServeSpec::default().apply_args(args)?;
    let model = match args.get("ckpt") {
        Some(p) => {
            // The checkpoint is self-describing: shape/mode flags would
            // either be redundant or silently ignored — reject them.
            for flag in ["model", "dim", "ffn", "layers", "heads", "vocab", "mode", "micro"] {
                if args.get(flag).is_some() {
                    bail!("--{flag} conflicts with --ckpt (the checkpoint is self-describing)");
                }
            }
            let ckpt = moss::coordinator::Checkpoint::load(std::path::Path::new(p))?;
            eprintln!(
                "checkpoint: model {} ({} layers, dim {}), mode {}, step {}",
                ckpt.spec.model.name(),
                ckpt.spec.layers,
                ckpt.spec.dim,
                ckpt.mode.name(),
                ckpt.step
            );
            ckpt.into_model()?
        }
        None => {
            if !args.has("synthetic") {
                bail!("serve needs --ckpt <path> or --synthetic (fresh seeded weights)");
            }
            let mut cfg = TrainConfig::default();
            cfg.host.model = moss::config::ModelKind::Transformer;
            let cfg = cfg.apply_args(args)?;
            Model::init(cfg.host, cfg.mode, cfg.seed)
        }
    };
    let mut engine = serve::Engine::new(model, serve_spec)?;
    let spec = *engine.model().spec();
    let sink = EventSink::from_args(args)?;
    if sink.active() {
        sink.emit(&run_start(
            "serve",
            engine.model().numerics().mode().name(),
            obj(vec![
                ("backend", jstr("serve")),
                ("model", jstr(spec.model.name())),
                ("layers", num(spec.layers as f64)),
                ("dim", num(spec.dim as f64)),
                ("heads", num(spec.heads as f64)),
                ("requests", num(serve_spec.requests as f64)),
                ("rate", num(serve_spec.rate)),
                ("max_batch", num(serve_spec.max_batch as f64)),
                ("threads", num(serve_spec.threads as f64)),
                ("max_ctx", num(serve_spec.max_ctx as f64)),
            ]),
        ));
        engine.set_sink(sink.clone());
    }
    eprintln!(
        "serve: model {} ({} layers, dim {}, {} heads), mode {}, weights packed once \
         ({:.1} KB resident); {} requests at {:.0} req/s, max_batch {}, {} threads, max_ctx {}",
        spec.model.name(),
        spec.layers,
        spec.dim,
        spec.heads,
        engine.model().numerics().mode().name(),
        engine.packed_bytes() as f64 / 1e3,
        serve_spec.requests,
        serve_spec.rate,
        serve_spec.max_batch,
        serve_spec.threads,
        serve_spec.max_ctx,
    );
    let reqs = serve::synthetic_requests(&serve_spec, spec.vocab);
    let report = engine.run(&reqs, DecodePath::Packed)?;
    println!(
        "serve done: {}/{} requests completed ({} rejected at admission), \
         {:.1} tok/s open-loop over {:.2}s, p50 {:.1} ms, p99 {:.1} ms, \
         occupancy {:.0}% ({:.1} mean active / {})",
        report.completions.len(),
        reqs.len(),
        report.rejected.len(),
        report.tokens_per_sec,
        report.wall_secs,
        report.p50_ms,
        report.p99_ms,
        report.occupancy * 100.0,
        report.mean_active,
        serve_spec.max_batch,
    );
    let (batch, plen, steps) = (serve_spec.max_batch, 8, 32);
    let tps_packed = serve::measure_decode_tps(&engine, DecodePath::Packed, batch, plen, steps)?;
    let tps_dequant =
        serve::measure_decode_tps(&engine, DecodePath::DequantF32, batch, plen, steps)?;
    println!(
        "decode closed-loop (batch {batch}): packed {:.1} tok/s vs f32-dequantize \
         {:.1} tok/s ({:.2}x)",
        tps_packed,
        tps_dequant,
        if tps_dequant > 0.0 { tps_packed / tps_dequant } else { 0.0 },
    );
    let bench_path = args.get_or("bench-out", "BENCH_serve.json");
    serve::write_bench_json(
        std::path::Path::new(bench_path),
        &engine,
        &report,
        tps_packed,
        tps_dequant,
    )?;
    eprintln!("wrote {bench_path}");
    if sink.active() {
        sink.emit(&Event::RunEnd {
            summary: obj(vec![
                ("completed", num(report.completions.len() as f64)),
                ("rejected", num(report.rejected.len() as f64)),
                ("tokens_per_sec", fnum(report.tokens_per_sec)),
                ("p50_ms", fnum(report.p50_ms)),
                ("p99_ms", fnum(report.p99_ms)),
                ("occupancy", fnum(report.occupancy)),
                ("decode_tps_packed", fnum(tps_packed)),
                ("decode_tps_dequant", fnum(tps_dequant)),
            ]),
        });
        let lines = sink.close()?;
        eprintln!("events: wrote {lines} lines to {}", args.get_or("events", "?"));
    }
    if args.has("assert-throughput") {
        if report.completions.len() != reqs.len() - report.rejected.len() {
            bail!(
                "workload did not drain: {} of {} admitted requests completed",
                report.completions.len(),
                reqs.len() - report.rejected.len()
            );
        }
        serve::throughput_gate(&engine, tps_packed, tps_dequant)?;
        eprintln!("throughput gate passed: packed decode >= f32-dequantize baseline");
    }
    Ok(())
}

/// `train --backend host --workers N`: the data-parallel host loop over
/// the distsim ring (packed u8 FP8 gradient payloads by default).
fn cmd_train_dist(args: &Args, cfg: TrainConfig) -> Result<()> {
    let spec = cfg.host;
    let schedule = match (cfg.dist.overlap, cfg.dist.zero2, cfg.dist.zero) {
        (false, false, false) => "serial",
        (true, false, false) => "overlapped buckets",
        (false, false, true) => "bucketed + zero-1",
        (true, false, true) => "overlapped buckets + zero-1",
        (false, true, _) => "bucketed + zero-2",
        (true, true, _) => "overlapped buckets + zero-2",
    };
    let topology = if cfg.dist.nodes > 1 {
        format!("hierarchical x{} nodes", cfg.dist.nodes)
    } else {
        "flat ring".to_string()
    };
    eprintln!(
        "dist host backend: model {}, mode {}, {} workers ({} shard, wire {}, {topology}, \
         {schedule}), vocab {} dim {} ffn {} layers {} ({} params), {} steps x {} \
         microbatches x {} accum",
        spec.model.name(),
        cfg.mode.name(),
        cfg.dist.workers,
        cfg.dist.shard.name(),
        cfg.dist.wire.name(),
        spec.vocab,
        spec.dim,
        spec.ffn,
        spec.layers,
        spec.param_count(),
        cfg.steps,
        spec.microbatches,
        cfg.dist.accum
    );
    let steps = cfg.steps;
    let mut trainer = DistTrainer::new(cfg)?;
    let sink = EventSink::from_args(args)?;
    if sink.active() {
        sink.emit(&run_start("train", trainer.cfg.mode.name(), host_spec_json(&trainer.cfg)));
        trainer.set_sink(sink.clone());
    }
    trainer.run(steps)?;
    let first = trainer.history.losses.first().map_or(f64::NAN, |&(_, l)| l);
    let tail = trainer.history.tail_loss(10);
    let comm = trainer.comm;
    println!(
        "done: {} steps, first loss {:.4}, final loss {:.4}, {:.0} tokens/s \
         (scaling {}: {} absmax calls)",
        trainer.steps_done,
        first,
        tail,
        trainer.throughput.tokens_per_sec(),
        trainer.scaler_name(),
        trainer.scaling_stats().absmax_calls,
    );
    println!(
        "wire {}: {:.2} B/elem, {:.0} bytes/step over {} grad elems, allreduce {:.2} ms/step",
        trainer.wire().name(),
        comm.bytes_per_elem(),
        comm.bytes_per_step(),
        comm.grad_elems,
        comm.allreduce_ms_per_step(),
    );
    if trainer.cfg.dist.overlap {
        println!(
            "overlap: {:.1}% of gradient comm hidden behind backward \
             ({:.2} ms hidden, {:.2} ms exposed per step, {} buckets)",
            trainer.overlap.overlap_ratio() * 100.0,
            trainer.overlap.hidden_ms_per_step(),
            trainer.overlap.exposed_ms_per_step(),
            trainer.buckets.len(),
        );
    }
    if trainer.cfg.dist.zero {
        println!(
            "zero-1: optimizer state {:.1} KB/rank (replicated would be {:.1} KB), \
             param all-gather {:.0} bytes/step ({:.2} ms/step)",
            trainer.zero1_state_bytes_per_rank() as f64 / 1e3,
            trainer.replicated_state_bytes() as f64 / 1e3,
            comm.param_bytes_per_step(),
            comm.param_gather_ms_per_step(),
        );
    }
    if trainer.cfg.dist.zero2 {
        println!(
            "zero-2: gradients {:.1} KB/rank retained after reduce-scatter \
             (replicated would be {:.1} KB)",
            trainer.grad_bytes_per_rank() as f64 / 1e3,
            trainer.replicated_grad_bytes() as f64 / 1e3,
        );
    }
    if sink.active() {
        sink.emit(&Event::RunEnd {
            summary: obj(vec![
                ("steps", num(trainer.steps_done as f64)),
                ("first_loss", fnum(first)),
                ("final_loss", fnum(tail)),
                ("tokens_per_sec", fnum(trainer.throughput.tokens_per_sec())),
                ("absmax_calls", num(trainer.scaling_stats().absmax_calls as f64)),
                ("wire_bytes_per_elem", fnum(comm.bytes_per_elem())),
                ("overlap_ratio", fnum(trainer.overlap.overlap_ratio())),
            ]),
        });
        let lines = sink.close()?;
        eprintln!("events: wrote {lines} lines to {}", args.get_or("events", "?"));
    }
    if let Some(out) = &trainer.cfg.out_dir {
        std::fs::create_dir_all(out)?;
        std::fs::write(out.join("losses.csv"), trainer.history.losses_csv())?;
        eprintln!("wrote {}/losses.csv", out.display());
    }
    if args.has("assert-improved") {
        if !first.is_finite() || !tail.is_finite() {
            bail!("non-finite loss: first {first}, final {tail}");
        }
        if tail >= first {
            bail!("loss did not decrease: first {first:.4} -> final {tail:.4}");
        }
        if trainer.cfg.dist.workers > 1 && comm.bytes_on_wire == 0 {
            let w = trainer.cfg.dist.workers;
            bail!("no gradient bytes crossed the wire in a {w}-worker run");
        }
        if trainer.cfg.dist.overlap
            && trainer.cfg.dist.workers > 1
            && trainer.overlap.hidden_secs <= 0.0
        {
            bail!(
                "--overlap hid zero communication ({:.2} ms exposed/step): the bucketed \
                 pipeline never ran concurrently with backward",
                trainer.overlap.exposed_ms_per_step()
            );
        }
        if trainer.cfg.dist.zero2 {
            let per = trainer.grad_bytes_per_rank() as f64;
            let even = trainer.replicated_grad_bytes() as f64 / trainer.cfg.dist.workers as f64;
            if per > even * 1.05 {
                bail!(
                    "zero-2 retained {per:.0} B/rank of gradients, above the 1/N + 5% \
                     bound ({even:.0} B even share)"
                );
            }
            eprintln!("zero-2 gradient shard bound held: {per:.0} B/rank <= {even:.0} B + 5%");
        }
        eprintln!("loss improved: {first:.4} -> {tail:.4}");
    }
    Ok(())
}

/// `repro kernels`: report what the GEMM hot path actually dispatched
/// to on this machine — the detected ISA, whether the vector path is
/// live, and the autotuner's cache. `--require-simd` turns "the probe
/// found a vector ISA" into the exit code; CI runs it on x86_64 so a
/// build that silently degrades to scalar fails loudly instead of just
/// benching slow. `--tune M,N,K` runs one on-the-spot search.
fn cmd_kernels(args: &Args) -> Result<()> {
    use moss::kernels::{simd, tune};
    let isa = simd::active_isa();
    println!("arch:        {}", std::env::consts::ARCH);
    println!("isa:         {isa}");
    println!("simd:        {}", if simd::simd_active() { "on" } else { "off (scalar)" });
    println!("tuner:       {}", if tune::enabled() { "on" } else { "off (MOSS_TUNE)" });
    println!("tuner cache: {}", tune::cache_path().display());
    if let Some(spec) = args.get("tune") {
        let dims: Vec<usize> =
            spec.split(',').map(|t| t.trim().parse::<usize>()).collect::<Result<_, _>>()?;
        let &[m, n, k] = &dims[..] else { bail!("--tune wants M,N,K (got {spec:?})") };
        let e = tune::tune_shape(m, n, k, moss::kernels::GemmConfig::default());
        println!(
            "tuned ({m}, {n}, {k}): nb {} threads {} ({:.2} gflop/s)",
            e.nb, e.threads, e.gflops
        );
    }
    let entries = tune::load_cache(&tune::cache_path());
    if entries.is_empty() {
        println!("cached:      0 shapes (searches run at trainer/engine construction)");
    } else {
        println!("cached:      {} shapes", entries.len());
        for e in entries {
            println!(
                "  ({:>5}, {:>5}, {:>5}) -> nb {:>3} threads {:>2}  {:>8.2} gflop/s",
                e.m, e.n, e.k, e.nb, e.threads, e.gflops
            );
        }
    }
    if args.has("require-simd") && !simd::simd_active() {
        bail!(
            "--require-simd: GEMM dispatch fell back to scalar on {} \
             (isa {isa}); unset MOSS_SIMD or investigate the feature probe",
            std::env::consts::ARCH
        );
    }
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let mut cfg = moss::config::presets::finetune_small(args.get_u64("steps", 200)?);
    cfg = cfg.apply_args(args)?;
    cfg.data = moss::config::DataKind::MathTasks;
    let rt = Arc::new(Runtime::load(&cfg.artifact_dir())?);
    let mut trainer = Trainer::new(rt.clone(), cfg.clone())?;
    trainer.run(cfg.steps)?;
    println!("finetune done: final loss {:.4}", trainer.history.tail_loss(20));
    let n = args.get_usize("eval-problems", 64)?;
    for kind in moss::data::TaskKind::ALL {
        let acc = moss::eval::eval_task_accuracy(&rt, &trainer.state, kind, n, cfg.seed)?;
        println!("  {:<12} accuracy: {:.1}%", kind.benchmark_name(), acc * 100.0);
    }
    Ok(())
}

/// Shape/seed payload for a host-backend `run_start` event. Everything
/// here is recoverable offline from the stream alone — the reader never
/// needs the original command line.
fn host_spec_json(cfg: &TrainConfig) -> Json {
    let spec = cfg.host;
    obj(vec![
        ("backend", jstr("host")),
        ("model", jstr(spec.model.name())),
        ("vocab", num(spec.vocab as f64)),
        ("dim", num(spec.dim as f64)),
        ("ffn", num(spec.ffn as f64)),
        ("layers", num(spec.layers as f64)),
        ("heads", num(spec.heads as f64)),
        ("seq", num(spec.seq as f64)),
        ("batch", num(spec.batch as f64)),
        ("microbatches", num(spec.microbatches as f64)),
        ("steps", num(cfg.steps as f64)),
        ("seed", num(cfg.seed as f64)),
        ("workers", num(cfg.dist.workers as f64)),
        ("nodes", num(cfg.dist.nodes as f64)),
        ("accum", num(cfg.dist.accum as f64)),
    ])
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = TrainConfig::default().apply_args(args)?;
    let rt = Runtime::load(&cfg.artifact_dir())?;
    let state = match args.get("ckpt") {
        Some(p) => moss::coordinator::checkpoint::load(std::path::Path::new(p), &rt)?,
        None => moss::coordinator::TrainState::init(&rt, cfg.seed as i32)?,
    };
    for (split, ppl) in moss::eval::perplexity::eval_three_splits(&rt, &state, 8)? {
        println!("{split:<10} ppl {ppl:.2}");
    }
    Ok(())
}
