//! Commodity substrates hand-rolled for the offline environment
//! (DESIGN.md "Environment substitutions"): JSON, RNG, statistics,
//! ASCII tables/plots, CSV.

pub mod json;
pub mod plot;
pub mod rng;
pub mod stats;
pub mod table;

/// Round `v` to `n` significant decimal digits (report formatting).
pub fn round_sig(v: f64, n: i32) -> f64 {
    if v == 0.0 || !v.is_finite() {
        return v;
    }
    let mag = v.abs().log10().floor() as i32;
    let f = 10f64.powi(n - 1 - mag);
    (v * f).round() / f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_sig_basic() {
        assert_eq!(round_sig(1234.5, 3), 1230.0);
        assert_eq!(round_sig(0.0012345, 2), 0.0012);
        assert_eq!(round_sig(0.0, 3), 0.0);
        assert_eq!(round_sig(-9.876, 2), -9.9);
    }
}
