//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`,
//! checkpoints and result files: objects, arrays, strings (with escapes),
//! numbers, bools, null. Preserves object insertion order (the manifest's
//! input order is the runtime calling convention).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object as ordered key/value pairs (insertion order preserved).
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // NaN/inf have no JSON token ("NaN" would make the
                    // whole document unparseable); write null, which
                    // tolerant readers surface as NaN.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builder for result files.
pub fn obj(kv: Vec<(&str, Json)>) -> Json {
    Json::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}, got {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character {:?} at offset {}", c as char, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                // High surrogate: JSON encodes astral
                                // chars as a \uXXXX\uXXXX UTF-16 pair.
                                // Combine with the low half; a lone or
                                // mismatched surrogate degrades to
                                // U+FFFD (tolerant, like bad \u values).
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if (0xDC00..0xE000).contains(&lo) {
                                        let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                        s.push(char::from_u32(c).unwrap_or('\u{fffd}'));
                                    } else {
                                        s.push('\u{fffd}');
                                        s.push(char::from_u32(lo).unwrap_or('\u{fffd}'));
                                    }
                                } else {
                                    s.push('\u{fffd}');
                                }
                            } else if (0xDC00..0xE000).contains(&cp) {
                                // Lone low surrogate.
                                s.push('\u{fffd}');
                            } else {
                                s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            }
                        }
                        c => bail!("invalid escape \\{}", c as char),
                    }
                }
                c => {
                    // Re-scan multi-byte UTF-8 sequences whole.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.b.len());
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    /// Four hex digits of a \uXXXX escape (cursor already past the 'u').
    fn hex4(&mut self) -> Result<u32> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        let cp = u32::from_str_radix(hex, 16)?;
        self.i += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

/// Parse a JSON object into a string->f64 map (flat metric files).
pub fn to_metric_map(j: &Json) -> BTreeMap<String, f64> {
    let mut m = BTreeMap::new();
    if let Json::Obj(kv) = j {
        for (k, v) in kv {
            if let Json::Num(n) = v {
                m.insert(k.clone(), *n);
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": 1, "b": [1.5, -2e3, true, null], "c": {"d": "x\ny"}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_str().unwrap(),
            "x\ny"
        );
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_manifest_like_structure() {
        let src = r#"{"programs":{"train_step_moss":{"file":"t.hlo.txt",
            "inputs":[{"name":"p.embed","dtype":"f32","shape":[256,64]}]}}}"#;
        let v = Json::parse(src).unwrap();
        let prog = v.get("programs").unwrap().get("train_step_moss").unwrap();
        let inp = &prog.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("dtype").unwrap().as_str().unwrap(), "f32");
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap()[0].as_usize().unwrap(), 256);
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo é");
    }

    fn round_trip(s: &str) {
        let j = Json::Str(s.to_string());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_str().unwrap(), s, "round trip of {s:?}");
    }

    #[test]
    fn string_escaping_round_trips() {
        round_trip("plain");
        round_trip(r#"quote " and backslash \"#);
        round_trip("newline\n tab\t cr\r");
        round_trip("control \u{1} \u{7} \u{1f} bytes");
        round_trip("nul \u{0} byte");
        round_trip("slash / stays literal");
        round_trip("non-ascii: é ü 日本語 Ω");
        round_trip("astral: 😀 𝕊 🦀");
        round_trip("mixed \"x\\y\"\n😀\tend");
    }

    #[test]
    fn parses_utf16_surrogate_pair_escapes() {
        // Writers that \u-escape astral chars (e.g. Python json.dumps
        // with ensure_ascii) emit UTF-16 pairs; they must decode to one
        // char, not two replacement chars. (Raw strings keep the \u
        // literal, so the *parser's* escape path is what runs here.)
        let v = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
        let v = Json::parse(r#""x\ud835\udd4ax""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "x𝕊x");
    }

    #[test]
    fn lone_surrogates_degrade_to_replacement_char() {
        // Lone high, lone low, and high + non-surrogate escape: all
        // tolerantly replaced, never a panic or an invalid char.
        assert_eq!(Json::parse(r#""\ud83d""#).unwrap().as_str().unwrap(), "\u{fffd}");
        assert_eq!(Json::parse(r#""\ude00""#).unwrap().as_str().unwrap(), "\u{fffd}");
        assert_eq!(Json::parse(r#""\ud83dx""#).unwrap().as_str().unwrap(), "\u{fffd}x");
        assert_eq!(Json::parse(r#""\ud83dA""#).unwrap().as_str().unwrap(), "\u{fffd}A");
        assert!(Json::parse(r#""\ud83d\ud8"#).is_err(), "truncated pair is an error");
    }

    #[test]
    fn control_chars_are_escaped_on_write() {
        let out = Json::Str("a\u{1}b".to_string()).to_string();
        assert_eq!(out, "\"a\\u0001b\"");
    }

    #[test]
    fn nonfinite_numbers_write_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        let doc = obj(vec![("loss", num(f64::NAN)), ("ok", num(1.5))]).to_string();
        assert_eq!(doc, r#"{"loss":null,"ok":1.5}"#);
        // The document stays parseable — the whole point.
        assert!(Json::parse(&doc).is_ok());
    }
}
