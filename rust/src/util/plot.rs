//! ASCII line plots — loss curves (Figs. 5-7) and scale trajectories
//! (Fig. 4) render directly into the terminal and EXPERIMENTS.md.

/// Render one or more named series into an ASCII plot of `w` x `h` chars.
/// Series are drawn with distinct glyphs; x is the sample index mapped to
/// [0, w) and y is min..max across all series.
pub fn multi_line_plot(title: &str, series: &[(&str, &[f64])], w: usize, h: usize) -> String {
    const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];
    let mut ymin = f64::INFINITY;
    let mut ymax = f64::NEG_INFINITY;
    for (_, ys) in series {
        for &y in ys.iter().filter(|y| y.is_finite()) {
            ymin = ymin.min(y);
            ymax = ymax.max(y);
        }
    }
    if !ymin.is_finite() || ymin == ymax {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; w]; h];
    for (si, (_, ys)) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        if ys.is_empty() {
            continue;
        }
        for (i, &y) in ys.iter().enumerate() {
            if !y.is_finite() {
                continue;
            }
            let x = if ys.len() == 1 { 0 } else { i * (w - 1) / (ys.len() - 1) };
            let fy = (y - ymin) / (ymax - ymin);
            let row = h - 1 - ((fy * (h - 1) as f64).round() as usize).min(h - 1);
            grid[row][x] = g;
        }
    }
    let mut out = format!("-- {title} --\n");
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:>10.4} |")
        } else if i == h - 1 {
            format!("{ymin:>10.4} |")
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&label);
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(w)));
    let legend: Vec<String> = series
        .iter()
        .enumerate()
        .map(|(i, (n, _))| format!("{} {}", GLYPHS[i % GLYPHS.len()], n))
        .collect();
    out.push_str(&format!("{:>12}{}\n", "", legend.join("   ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plot_contains_series_glyphs_and_bounds() {
        let a: Vec<f64> = (0..50).map(|i| 5.0 - i as f64 * 0.05).collect();
        let b: Vec<f64> = (0..50).map(|i| 5.0 - i as f64 * 0.04).collect();
        let p = multi_line_plot("loss", &[("bf16", &a), ("moss", &b)], 60, 12);
        assert!(p.contains('*') && p.contains('o'));
        assert!(p.contains("5.0000"));
        assert!(p.contains("bf16") && p.contains("moss"));
    }

    #[test]
    fn handles_constant_series() {
        let a = [1.0; 10];
        let p = multi_line_plot("c", &[("x", &a[..])], 20, 5);
        assert!(p.contains('*'));
    }

    #[test]
    fn handles_single_point() {
        let p = multi_line_plot("p", &[("x", &[2.0][..])], 10, 4);
        assert!(p.contains('*'));
    }
}
