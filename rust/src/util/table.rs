//! ASCII table rendering for paper-table reproduction output.

/// A simple column-aligned table with a title, printed in the style the
/// report binaries use for every reproduced paper table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:<w$}", c, w = width[i] + 2));
            }
            out.push('\n');
        };
        line(&mut out, &self.header);
        out.push_str(&"-".repeat(width.iter().map(|w| w + 2).sum::<usize>()));
        out.push('\n');
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    /// Render as CSV (written next to the ASCII rendering in results/).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format helper: fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format helper: "+x.y%" relative change.
pub fn pct(new: f64, base: f64) -> String {
    format!("{:+.1}%", (new / base - 1.0) * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        let lines: Vec<_> = s.lines().collect();
        // all data lines same width structure
        assert!(lines[1].starts_with("a"));
        assert!(lines[3].starts_with("1"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("T", &["a,b"]);
        t.row(vec!["x\"y".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"x\"\"y\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
