//! Small statistics toolkit for benches and reports.

/// Summary statistics of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Percentile (linear interpolation) of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Geometric mean (Table 7 reports one).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Exponential moving average tracker (loss smoothing).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v * (1.0 - self.alpha) + x * self.alpha,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Mean of a slice of f32 (metric helpers).
pub fn mean_f32(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len().max(1) as f64
}

/// Max |x| of a slice — the max-reduction that JIT scaling pays for.
pub fn absmax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |acc, &x| acc.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 50.0), 5.0);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.update(4.0);
        }
        assert!((e.get().unwrap() - 4.0).abs() < 1e-6);
    }

    #[test]
    fn absmax_handles_negatives() {
        assert_eq!(absmax(&[-3.0, 2.0]), 3.0);
        assert_eq!(absmax(&[]), 0.0);
    }
}
