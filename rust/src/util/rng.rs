//! Deterministic RNG substrate (rand is unavailable offline).
//!
//! SplitMix64 core + helpers for uniform/normal/Zipf sampling. Every
//! stochastic component in the framework (data generation, init seeds,
//! worker shards) derives from one of these so runs are reproducible
//! from a single seed.

/// SplitMix64: tiny, fast, passes BigCrush as a 64-bit mixer.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (worker shards, per-layer keys).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xA24BAED4963EE407));
        r.next_u64(); // decorrelate
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free variant is fine here:
        // bias < 2^-64 * n, negligible for n << 2^32.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal (Box-Muller; one value per call for simplicity).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill with activation-like data: lognormal channel envelope along
    /// the last dim (multi-octave structure, the paper's Table-7 regime).
    pub fn activation_like(&mut self, rows: usize, cols: usize, chan_sigma: f64) -> Vec<f32> {
        let env: Vec<f64> = (0..cols).map(|_| (self.normal() * chan_sigma).exp()).collect();
        let mut out = Vec::with_capacity(rows * cols);
        for _ in 0..rows {
            let tok = (self.normal() * 0.5).exp();
            for e in &env {
                out.push((self.normal() * e * tok) as f32);
            }
        }
        out
    }

    /// Sample from a Zipf(alpha) distribution over [0, n) via inverse CDF
    /// on a precomputed table.
    pub fn zipf(&mut self, table: &ZipfTable) -> usize {
        let u = self.f64();
        match table.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(table.cdf.len() - 1),
        }
    }
}

/// Derive data-parallel worker `rank`'s independent stream seed from
/// the run seed: a splitmix64 mix of `seed ^ rank` (the same mixer the
/// RNG core uses), so shard streams are decorrelated across ranks but
/// fully determined by `(seed, rank)` — two runs of the same config are
/// bit-identical.
pub fn stream_seed(seed: u64, rank: u64) -> u64 {
    let mut r = Rng::new(seed ^ rank.wrapping_mul(0xA24BAED4963EE407));
    r.next_u64()
}

/// Precomputed Zipf CDF (vocabulary-scale tables are built once).
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, alpha: f64) -> Self {
        let mut w: Vec<f64> = (1..=n).map(|k| (k as f64).powf(-alpha)).collect();
        let total: f64 = w.iter().sum();
        let mut acc = 0.0;
        for x in w.iter_mut() {
            acc += *x / total;
            *x = acc;
        }
        ZipfTable { cdf: w }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let r = Rng::new(42);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn stream_seeds_are_deterministic_and_distinct() {
        let a: Vec<u64> = (0..8).map(|r| stream_seed(42, r)).collect();
        let b: Vec<u64> = (0..8).map(|r| stream_seed(42, r)).collect();
        assert_eq!(a, b, "stream seeds must be reproducible");
        for i in 0..8 {
            for j in 0..i {
                assert_ne!(a[i], a[j], "ranks {i} and {j} collided");
            }
            assert_ne!(a[i], 42, "stream seed must not echo the run seed");
        }
        // and a different run seed moves every stream
        let c: Vec<u64> = (0..8).map(|r| stream_seed(43, r)).collect();
        assert!(a.iter().zip(&c).all(|(x, y)| x != y));
    }

    #[test]
    fn zipf_is_skewed_toward_head() {
        let t = ZipfTable::new(1000, 1.2);
        let mut r = Rng::new(11);
        let mut head = 0;
        let n = 10_000;
        for _ in 0..n {
            if r.zipf(&t) < 10 {
                head += 1;
            }
        }
        // top-10 of a 1000-symbol Zipf(1.2) should carry a large mass
        assert!(head as f64 / n as f64 > 0.35, "{head}");
    }
}
