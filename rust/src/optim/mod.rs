//! Host-side AdamW reference + the Theorem-2 bound machinery.
//!
//! The *production* optimizer runs inside the AOT `train_step` HLO (L2);
//! this module is the verification substrate: property tests of the
//! bounded-update theorem that automatic scaling rests on, and the
//! host-side mirror used by unit tests and the distributed simulator.

pub mod adamw;
pub mod bound;

pub use adamw::{AdamW, AdamWParams};
pub use bound::{predicted_absmax, update_bound};
