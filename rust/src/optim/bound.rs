//! Theorem 2 (paper Eq. 8) — the bounded-update property automatic
//! scaling is built on.

/// Bound on `|Delta_t| / eta` at (1-based) step `t`:
/// `max(1, (1-b1^t)/sqrt(1-b2^t))` collapsed per Eq. 8 (the ratio
/// exceeds 1 only in the sparse-gradient corner case).
pub fn update_bound(t: u64, beta1: f32, beta2: f32) -> f32 {
    let t = t as f64;
    let num = 1.0 - (beta1 as f64).powf(t);
    let den = (1.0 - (beta2 as f64).powf(t)).sqrt();
    if num > den {
        (num / den) as f32
    } else {
        1.0
    }
}

/// Eq. 10 generalized to a schedule: `max|W_t| <= max|W_0| + sum eta_i`.
pub fn predicted_absmax(absmax0: f32, lr_sum: f32) -> f32 {
    absmax0 + lr_sum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn late_steps_bound_is_one() {
        assert_eq!(update_bound(10_000, 0.9, 0.95), 1.0);
    }

    #[test]
    fn paper_defaults_stay_practically_at_eta() {
        // With b1=0.9, b2=0.95 the ratio (1-b1^t)/sqrt(1-b2^t) is below
        // 1 only for t <~ 8 and peaks around 1.10 near t~21 before
        // decaying back to 1 — i.e. the paper's "|Delta_t| <= eta" holds
        // up to a ~10% early-phase correction (which the warmup schedule
        // and the /448 scale conversion absorb; a finding worth noting —
        // see EXPERIMENTS.md).
        for t in 1..=7 {
            assert_eq!(update_bound(t, 0.9, 0.95), 1.0, "t={t}");
        }
        let peak = (1..2000).map(|t| update_bound(t, 0.9, 0.95)).fold(0f32, f32::max);
        assert!(peak < 1.11, "peak {peak}");
        assert!(update_bound(100_000, 0.9, 0.95) <= 1.0 + 1e-6);
    }

    #[test]
    fn adam_classic_betas_exceed_one_early() {
        // beta2=0.999: den at t=1 is sqrt(0.001)=0.0316 < num 0.1
        let b = update_bound(1, 0.9, 0.999);
        assert!(b > 3.0 && b < 3.3, "{b}");
        // decays back toward 1
        assert!(update_bound(100, 0.9, 0.999) > 1.0);
        assert_eq!(update_bound(100_000, 0.9, 0.999), 1.0);
    }

    #[test]
    fn predicted_absmax_is_additive() {
        assert_eq!(predicted_absmax(2.0, 0.5), 2.5);
    }
}
