//! Host-side AdamW (paper Eq. 1) over flat f32 buffers.

/// AdamW hyperparameters (paper §4.1 defaults).
#[derive(Debug, Clone, Copy)]
pub struct AdamWParams {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamWParams {
    fn default() -> Self {
        AdamWParams { beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.1 }
    }
}

/// One parameter tensor's optimizer state.
#[derive(Debug, Clone)]
pub struct AdamW {
    pub hp: AdamWParams,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// 1-based step counter.
    pub t: u64,
}

impl AdamW {
    pub fn new(n: usize, hp: AdamWParams) -> Self {
        AdamW { hp, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// In-place update of `w` with gradient `g` at learning rate `lr`
    /// (paper Eq. 1, decoupled weight decay).
    pub fn step(&mut self, w: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(w.len(), self.m.len());
        self.begin_step();
        self.step_range(w, g, lr, 0);
    }

    /// Advance the shared step counter (the bias-correction clock).
    /// Call exactly once per optimizer step before any
    /// [`Self::step_range`] call of that step — the ZeRO-1 path applies
    /// one `begin_step` and then several subrange applies against the
    /// same state.
    pub fn begin_step(&mut self) {
        self.t += 1;
    }

    /// Subrange AdamW apply: update `w` from `g` using state entries
    /// `[off, off + g.len())`. Elementwise bit-identical to
    /// [`Self::step`] over the same elements — the update is local per
    /// element, so a sharded optimizer (each rank owning a slice of the
    /// flat parameter vector) reproduces the replicated trajectory bit
    /// for bit. Requires a prior [`Self::begin_step`] this step.
    pub fn step_range(&mut self, w: &mut [f32], g: &[f32], lr: f32, off: usize) {
        assert_eq!(w.len(), g.len());
        assert!(off + g.len() <= self.m.len(), "state subrange out of bounds");
        assert!(self.t > 0, "step_range requires begin_step first");
        let t = self.t as f64;
        let bc1 = 1.0 - (self.hp.beta1 as f64).powf(t);
        let bc2 = 1.0 - (self.hp.beta2 as f64).powf(t);
        let (b1, b2) = (self.hp.beta1, self.hp.beta2);
        for i in 0..w.len() {
            let j = off + i;
            self.m[j] = b1 * self.m[j] + (1.0 - b1) * g[i];
            self.v[j] = b2 * self.v[j] + (1.0 - b2) * g[i] * g[i];
            let mhat = self.m[j] as f64 / bc1;
            let vhat = self.v[j] as f64 / bc2;
            let upd = mhat / (vhat.sqrt() + self.hp.eps as f64)
                + self.hp.weight_decay as f64 * w[i] as f64;
            w[i] -= (lr as f64 * upd) as f32;
        }
    }

    /// Optimizer-state bytes this instance holds (`m` + `v`, f32 each)
    /// — what the ZeRO-1 per-rank footprint gate measures.
    pub fn state_bytes(&self) -> u64 {
        ((self.m.len() + self.v.len()) * std::mem::size_of::<f32>()) as u64
    }
}

#[cfg(test)]
mod tests {
    use crate::util::rng::Rng;

    use super::*;

    #[test]
    fn descends_a_quadratic() {
        // minimize f(w) = |w - 3|^2 / 2; gradient = w - 3
        let mut w = vec![0f32];
        let mut opt = AdamW::new(1, AdamWParams { weight_decay: 0.0, ..Default::default() });
        for _ in 0..2000 {
            let g = vec![w[0] - 3.0];
            opt.step(&mut w, &g, 1e-2);
        }
        assert!((w[0] - 3.0).abs() < 0.05, "{}", w[0]);
    }

    #[test]
    fn update_magnitude_bounded_by_lr_times_bound() {
        // Theorem 2 along a heavy-tailed gradient trajectory.
        let mut rng = Rng::new(17);
        let mut w = vec![0.5f32; 8];
        let mut opt = AdamW::new(8, AdamWParams::default());
        let lr = 1e-3f32;
        for t in 1..=100u64 {
            let g: Vec<f32> = (0..8)
                .map(|_| (rng.normal() * 10f64.powf(rng.range_f64(-3.0, 3.0))) as f32)
                .collect();
            let before = w.clone();
            opt.step(&mut w, &g, lr);
            let bound = lr * super::super::bound::update_bound(t, 0.9, 0.95);
            for i in 0..8 {
                let delta = (w[i] - before[i]).abs();
                let wd = lr * 0.1 * before[i].abs();
                assert!(delta <= bound * 1.0001 + wd + 1e-7,
                        "t={t} delta={delta} bound={bound}");
            }
        }
    }

    /// Sharded application (one `begin_step`, several `step_range`
    /// pieces at arbitrary split points) is bit-identical to the
    /// monolithic `step` over multiple optimizer steps — the ZeRO-1
    /// correctness core.
    #[test]
    fn step_range_shards_are_bitwise_identical_to_step() {
        let n = 23usize;
        let mut rng = Rng::new(41);
        let mut w_mono = vec![0.3f32; n];
        let mut w_shard = w_mono.clone();
        let mut mono = AdamW::new(n, AdamWParams::default());
        let mut shard = AdamW::new(n, AdamWParams::default());
        for step in 0..5 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            mono.step(&mut w_mono, &g, 2e-3);
            shard.begin_step();
            // uneven split points, including a zero-length piece
            let cuts = [0usize, 7, 7, 16, n];
            for p in 0..cuts.len() - 1 {
                let (lo, hi) = (cuts[p], cuts[p + 1]);
                shard.step_range(&mut w_shard[lo..hi], &g[lo..hi], 2e-3, lo);
            }
            for i in 0..n {
                assert_eq!(w_mono[i].to_bits(), w_shard[i].to_bits(), "step {step} elem {i}");
                assert_eq!(mono.m[i].to_bits(), shard.m[i].to_bits());
                assert_eq!(mono.v[i].to_bits(), shard.v[i].to_bits());
            }
            assert_eq!(mono.t, shard.t);
        }
    }

    /// A fresh state whose length equals only its shard behaves exactly
    /// like the same slice of a full-length replicated state (the 1/N
    /// memory claim costs no fidelity).
    #[test]
    fn shard_local_state_matches_replicated_slice() {
        let n = 12usize;
        let (lo, hi) = (5usize, 11usize);
        let mut rng = Rng::new(43);
        let mut w_full = vec![0.1f32; n];
        let mut w_shard: Vec<f32> = w_full[lo..hi].to_vec();
        let mut full = AdamW::new(n, AdamWParams::default());
        let mut local = AdamW::new(hi - lo, AdamWParams::default());
        for _ in 0..4 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
            full.step(&mut w_full, &g, 1e-3);
            local.step(&mut w_shard, &g[lo..hi], 1e-3);
        }
        for (a, b) in w_full[lo..hi].iter().zip(&w_shard) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(local.state_bytes(), 2 * 4 * (hi - lo) as u64);
    }

    #[test]
    #[should_panic(expected = "begin_step")]
    fn step_range_requires_begin_step() {
        let mut opt = AdamW::new(4, AdamWParams::default());
        let mut w = vec![0f32; 4];
        opt.step_range(&mut w, &[1.0, 1.0, 1.0, 1.0], 1e-3, 0);
    }

    #[test]
    fn scale_invariance_of_adam_direction() {
        // paper §2.2: g and 256*g give the same (wd=0, eps->0) update.
        let hp = AdamWParams { weight_decay: 0.0, eps: 1e-30, ..Default::default() };
        let g1 = vec![0.3f32, -2.0, 5.0];
        let g2: Vec<f32> = g1.iter().map(|x| x * 256.0).collect();
        let mut wa = vec![1.0f32; 3];
        let mut wb = vec![1.0f32; 3];
        AdamW::new(3, hp).step(&mut wa, &g1, 1e-3);
        AdamW::new(3, hp).step(&mut wb, &g2, 1e-3);
        for (a, b) in wa.iter().zip(&wb) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
