//! Host-side AdamW (paper Eq. 1) over flat f32 buffers.

/// AdamW hyperparameters (paper §4.1 defaults).
#[derive(Debug, Clone, Copy)]
pub struct AdamWParams {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamWParams {
    fn default() -> Self {
        AdamWParams { beta1: 0.9, beta2: 0.95, eps: 1e-8, weight_decay: 0.1 }
    }
}

/// One parameter tensor's optimizer state.
#[derive(Debug, Clone)]
pub struct AdamW {
    pub hp: AdamWParams,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// 1-based step counter.
    pub t: u64,
}

impl AdamW {
    pub fn new(n: usize, hp: AdamWParams) -> Self {
        AdamW { hp, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// In-place update of `w` with gradient `g` at learning rate `lr`
    /// (paper Eq. 1, decoupled weight decay).
    pub fn step(&mut self, w: &mut [f32], g: &[f32], lr: f32) {
        assert_eq!(w.len(), g.len());
        assert_eq!(w.len(), self.m.len());
        self.t += 1;
        let t = self.t as f64;
        let bc1 = 1.0 - (self.hp.beta1 as f64).powf(t);
        let bc2 = 1.0 - (self.hp.beta2 as f64).powf(t);
        let (b1, b2) = (self.hp.beta1, self.hp.beta2);
        for i in 0..w.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g[i];
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g[i] * g[i];
            let mhat = self.m[i] as f64 / bc1;
            let vhat = self.v[i] as f64 / bc2;
            let upd = mhat / (vhat.sqrt() + self.hp.eps as f64)
                + self.hp.weight_decay as f64 * w[i] as f64;
            w[i] -= (lr as f64 * upd) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::util::rng::Rng;

    use super::*;

    #[test]
    fn descends_a_quadratic() {
        // minimize f(w) = |w - 3|^2 / 2; gradient = w - 3
        let mut w = vec![0f32];
        let mut opt = AdamW::new(1, AdamWParams { weight_decay: 0.0, ..Default::default() });
        for _ in 0..2000 {
            let g = vec![w[0] - 3.0];
            opt.step(&mut w, &g, 1e-2);
        }
        assert!((w[0] - 3.0).abs() < 0.05, "{}", w[0]);
    }

    #[test]
    fn update_magnitude_bounded_by_lr_times_bound() {
        // Theorem 2 along a heavy-tailed gradient trajectory.
        let mut rng = Rng::new(17);
        let mut w = vec![0.5f32; 8];
        let mut opt = AdamW::new(8, AdamWParams::default());
        let lr = 1e-3f32;
        for t in 1..=100u64 {
            let g: Vec<f32> = (0..8)
                .map(|_| (rng.normal() * 10f64.powf(rng.range_f64(-3.0, 3.0))) as f32)
                .collect();
            let before = w.clone();
            opt.step(&mut w, &g, lr);
            let bound = lr * super::super::bound::update_bound(t, 0.9, 0.95);
            for i in 0..8 {
                let delta = (w[i] - before[i]).abs();
                let wd = lr * 0.1 * before[i].abs();
                assert!(delta <= bound * 1.0001 + wd + 1e-7,
                        "t={t} delta={delta} bound={bound}");
            }
        }
    }

    #[test]
    fn scale_invariance_of_adam_direction() {
        // paper §2.2: g and 256*g give the same (wd=0, eps->0) update.
        let hp = AdamWParams { weight_decay: 0.0, eps: 1e-30, ..Default::default() };
        let g1 = vec![0.3f32, -2.0, 5.0];
        let g2: Vec<f32> = g1.iter().map(|x| x * 256.0).collect();
        let mut wa = vec![1.0f32; 3];
        let mut wb = vec![1.0f32; 3];
        AdamW::new(3, hp).step(&mut wa, &g1, 1e-3);
        AdamW::new(3, hp).step(&mut wb, &g2, 1e-3);
        for (a, b) in wa.iter().zip(&wb) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
