//! Byte-level tokenizer with optional learned BPE merges — the substrate
//! for feeding real text through the framework (the synthetic corpus
//! path generates token ids directly).
//!
//! Vocabulary layout: 0 = PAD/BOS, 1..=256 = raw bytes (byte b -> b+1),
//! 257.. = learned merges in creation order.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Byte-level tokenizer + greedy BPE.
#[derive(Debug, Clone)]
pub struct ByteTokenizer {
    /// Learned merges: (left, right) -> new token id, in rank order.
    merges: Vec<(u32, u32)>,
    merge_rank: HashMap<(u32, u32), u32>,
}

pub const PAD: u32 = 0;
pub const BYTE_BASE: u32 = 1;
pub const FIRST_MERGE: u32 = 257;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer { merges: Vec::new(), merge_rank: HashMap::new() }
    }

    /// Train `n_merges` BPE merges on `corpus` (greedy most-frequent-pair).
    /// A corpus too small to contain even one pair cannot support any
    /// merge — that is a configuration error, not a silent no-op.
    pub fn train(corpus: &[u8], n_merges: usize) -> Result<Self> {
        if n_merges > 0 && corpus.len() < 2 {
            bail!(
                "corpus of {} byte(s) cannot support BPE merges (need at least one \
                 adjacent pair); use n_merges = 0 for plain byte-level tokenization",
                corpus.len()
            );
        }
        let mut tok = ByteTokenizer::new();
        let mut seq: Vec<u32> = corpus.iter().map(|&b| b as u32 + BYTE_BASE).collect();
        for _ in 0..n_merges {
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            let Some((&pair, &cnt)) = counts.iter().max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if cnt < 2 {
                break;
            }
            let id = FIRST_MERGE + tok.merges.len() as u32;
            tok.merge_rank.insert(pair, id);
            tok.merges.push(pair);
            seq = merge_pass(&seq, pair, id);
        }
        Ok(tok)
    }

    pub fn vocab_size(&self) -> usize {
        FIRST_MERGE as usize + self.merges.len()
    }

    /// Encode bytes to token ids (applies merges in rank order).
    pub fn encode(&self, text: &[u8]) -> Vec<u32> {
        let mut seq: Vec<u32> = text.iter().map(|&b| b as u32 + BYTE_BASE).collect();
        for (i, &pair) in self.merges.iter().enumerate() {
            let id = FIRST_MERGE + i as u32;
            if seq.len() < 2 {
                break;
            }
            seq = merge_pass(&seq, pair, id);
        }
        seq
    }

    /// Decode token ids back to bytes. A token outside the learned
    /// vocabulary is a caller error (a corrupt sample or a model/
    /// tokenizer vocab mismatch), surfaced as a `Result` rather than an
    /// out-of-bounds panic mid-pipeline.
    pub fn decode(&self, toks: &[u32]) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        for &t in toks {
            self.decode_one(t, &mut out)?;
        }
        Ok(out)
    }

    fn decode_one(&self, t: u32, out: &mut Vec<u8>) -> Result<()> {
        if t == PAD {
            return Ok(());
        }
        if t < FIRST_MERGE {
            out.push((t - BYTE_BASE) as u8);
            return Ok(());
        }
        let Some(&(l, r)) = self.merges.get((t - FIRST_MERGE) as usize) else {
            bail!("token {t} out of vocabulary (size {})", self.vocab_size());
        };
        self.decode_one(l, out)?;
        self.decode_one(r, out)
    }
}

impl Default for ByteTokenizer {
    fn default() -> Self {
        Self::new()
    }
}

fn merge_pass(seq: &[u32], pair: (u32, u32), id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(seq.len());
    let mut i = 0;
    while i < seq.len() {
        if i + 1 < seq.len() && (seq[i], seq[i + 1]) == pair {
            out.push(id);
            i += 2;
        } else {
            out.push(seq[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_roundtrip_without_merges() {
        let t = ByteTokenizer::new();
        let text = b"hello, world! \xf0\x9f\x99\x82";
        assert_eq!(t.decode(&t.encode(text)).unwrap(), text.to_vec());
    }

    #[test]
    fn bpe_learns_frequent_pairs_and_roundtrips() {
        let corpus = b"the cat sat on the mat the cat sat on the mat".repeat(10);
        let t = ByteTokenizer::train(&corpus, 20).unwrap();
        // may stop early once no pair repeats; must learn most merges
        assert!(t.vocab_size() > 257 + 10 && t.vocab_size() <= 257 + 20);
        let enc = t.encode(&corpus);
        assert!(enc.len() < corpus.len(), "compression expected");
        assert_eq!(t.decode(&enc).unwrap(), corpus);
    }

    #[test]
    fn merge_determinism() {
        let corpus = b"abababab".to_vec();
        let a = ByteTokenizer::train(&corpus, 4).unwrap();
        let b = ByteTokenizer::train(&corpus, 4).unwrap();
        assert_eq!(a.encode(b"abab"), b.encode(b"abab"));
    }

    #[test]
    fn empty_input() {
        // merge-free tokenization of nothing is fine...
        let t = ByteTokenizer::train(b"", 0).unwrap();
        assert!(t.encode(b"").is_empty());
        assert!(t.decode(&[]).unwrap().is_empty());
        // ...but asking for merges from a degenerate corpus is a config
        // error, not a silent no-op (tiny and single-byte alike)
        assert!(ByteTokenizer::train(b"", 5).is_err());
        assert!(ByteTokenizer::train(b"x", 5).is_err());
    }

    #[test]
    fn out_of_vocab_decode_is_an_error() {
        let t = ByteTokenizer::train(b"abababab", 2).unwrap();
        let bad = t.vocab_size() as u32; // one past the last merge id
        let err = t.decode(&[BYTE_BASE, bad]).unwrap_err().to_string();
        assert!(err.contains("out of vocabulary"), "{err}");
        // in-vocab ids still decode after the hardening
        assert_eq!(t.decode(&[FIRST_MERGE]).unwrap(), b"ab".to_vec());
    }
}
