//! Arithmetic-reasoning task generator — the stand-in for the paper's
//! fine-tuning datasets and benchmarks (MAmmoTH training; Mathematics /
//! GSM8K / NumGLUE evaluation; DESIGN.md "Environment substitutions").
//!
//! Three task families of increasing structure:
//! * [`TaskKind::Arithmetic`]  — `a+b=` / `a-b=`            (Mathematics)
//! * [`TaskKind::MultiStep`]   — `a+b-c=`                   (GSM8K)
//! * [`TaskKind::Compare`]     — `max(a,b)=` rendered `a?b=` (NumGLUE)
//!
//! Problems render into a fixed symbolic token alphabet that fits any
//! model vocab >= 32; exact-match decoding of the answer digits is the
//! accuracy metric (paper Tables 3/4/11).

use crate::util::rng::Rng;

/// Token alphabet (kept below 32 so every preset vocab can host it).
pub const PAD: i32 = 0;
pub const EOS: i32 = 2;
pub const DIGIT_BASE: i32 = 3; // '0'..'9' -> 3..12
pub const PLUS: i32 = 13;
pub const MINUS: i32 = 14;
pub const EQUALS: i32 = 16;
pub const CMP: i32 = 18; // the "which is larger?" operator
pub const NEG: i32 = 19; // unary minus for negative answers

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    Arithmetic,
    MultiStep,
    Compare,
}

impl TaskKind {
    pub const ALL: [TaskKind; 3] = [TaskKind::Arithmetic, TaskKind::MultiStep, TaskKind::Compare];

    /// Paper benchmark this family stands in for.
    pub fn benchmark_name(&self) -> &'static str {
        match self {
            TaskKind::Arithmetic => "Mathematics",
            TaskKind::MultiStep => "GSM8K",
            TaskKind::Compare => "NumGLUE",
        }
    }
}

/// One generated problem: prompt tokens (ending in `=`) and the answer
/// token sequence (digits, possibly `NEG`-prefixed, no EOS).
#[derive(Debug, Clone)]
pub struct Problem {
    pub prompt: Vec<i32>,
    pub answer: Vec<i32>,
}

/// Deterministic task stream.
pub struct TaskGenerator {
    pub kind: TaskKind,
    rng: Rng,
    /// Operand range [0, max_operand).
    pub max_operand: i64,
}

impl TaskGenerator {
    pub fn new(kind: TaskKind, seed: u64) -> Self {
        TaskGenerator { kind, rng: Rng::new(seed).fork(kind as u64 + 1), max_operand: 100 }
    }

    pub fn next_problem(&mut self) -> Problem {
        let a = self.rng.below(self.max_operand as u64) as i64;
        let b = self.rng.below(self.max_operand as u64) as i64;
        match self.kind {
            TaskKind::Arithmetic => {
                if self.rng.f64() < 0.5 {
                    Problem { prompt: render_binop(a, PLUS, b), answer: digits(a + b) }
                } else {
                    Problem { prompt: render_binop(a, MINUS, b), answer: digits(a - b) }
                }
            }
            TaskKind::MultiStep => {
                let c = self.rng.below(self.max_operand as u64) as i64;
                let mut p = render_binop(a, PLUS, b);
                p.pop(); // strip '='
                p.push(MINUS);
                p.extend(digits(c));
                p.push(EQUALS);
                Problem { prompt: p, answer: digits(a + b - c) }
            }
            TaskKind::Compare => {
                Problem { prompt: render_binop(a, CMP, b), answer: digits(a.max(b)) }
            }
        }
    }

    /// A full training sequence: prompt + answer + EOS, loss over all
    /// positions, padded/truncated to `seq_plus_1`.
    pub fn training_sequence(&mut self, seq_plus_1: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(seq_plus_1);
        while out.len() < seq_plus_1 {
            let p = self.next_problem();
            out.extend_from_slice(&p.prompt);
            out.extend_from_slice(&p.answer);
            out.push(EOS);
        }
        out.truncate(seq_plus_1);
        out
    }
}

/// Render `a <op> b =` as tokens.
fn render_binop(a: i64, op: i32, b: i64) -> Vec<i32> {
    let mut t = digits(a);
    t.push(op);
    t.extend(digits(b));
    t.push(EQUALS);
    t
}

/// Decimal digits of `n` as tokens (NEG-prefixed when negative).
pub fn digits(n: i64) -> Vec<i32> {
    let mut out = Vec::new();
    if n < 0 {
        out.push(NEG);
    }
    let s = n.abs().to_string();
    out.extend(s.bytes().map(|b| DIGIT_BASE + (b - b'0') as i32));
    out
}

/// Parse an answer token sequence back to an integer (None if malformed).
pub fn parse_answer(toks: &[i32]) -> Option<i64> {
    let (neg, rest) = match toks.split_first() {
        Some((&NEG, rest)) => (true, rest),
        _ => (false, toks),
    };
    if rest.is_empty() {
        return None;
    }
    let mut v: i64 = 0;
    for &t in rest {
        let d = t - DIGIT_BASE;
        if !(0..=9).contains(&d) {
            return None;
        }
        v = v * 10 + d as i64;
    }
    Some(if neg { -v } else { v })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_roundtrip() {
        for n in [-123i64, -1, 0, 7, 42, 999] {
            assert_eq!(parse_answer(&digits(n)), Some(n), "{n}");
        }
    }

    #[test]
    fn problems_are_solvable_and_consistent() {
        for kind in TaskKind::ALL {
            let mut g = TaskGenerator::new(kind, 11);
            for _ in 0..50 {
                let p = g.next_problem();
                assert_eq!(*p.prompt.last().unwrap(), EQUALS);
                assert!(parse_answer(&p.answer).is_some(), "{kind:?}");
                assert!(p.prompt.iter().all(|&t| t > 0 && t < 32));
            }
        }
    }

    #[test]
    fn arithmetic_answers_are_correct() {
        let mut g = TaskGenerator::new(TaskKind::Arithmetic, 3);
        for _ in 0..20 {
            let p = g.next_problem();
            // re-parse the prompt and verify
            let eq = p.prompt.len() - 1;
            let op_pos = p.prompt.iter().position(|&t| t == PLUS || t == MINUS).unwrap();
            let a = parse_answer(&p.prompt[..op_pos]).unwrap();
            let b = parse_answer(&p.prompt[op_pos + 1..eq]).unwrap();
            let want = if p.prompt[op_pos] == PLUS { a + b } else { a - b };
            assert_eq!(parse_answer(&p.answer), Some(want));
        }
    }

    #[test]
    fn training_sequence_has_requested_length() {
        let mut g = TaskGenerator::new(TaskKind::MultiStep, 5);
        let s = g.training_sequence(129);
        assert_eq!(s.len(), 129);
    }

    #[test]
    fn deterministic_streams() {
        let mut a = TaskGenerator::new(TaskKind::Compare, 9);
        let mut b = TaskGenerator::new(TaskKind::Compare, 9);
        for _ in 0..10 {
            assert_eq!(a.next_problem().prompt, b.next_problem().prompt);
        }
    }
}
