//! Zipf-Markov synthetic language — the Dolma-corpus stand-in
//! (DESIGN.md "Environment substitutions").
//!
//! Token t+1 is drawn from a context-conditioned candidate set: the
//! hashed (t-1, t) context deterministically selects `branching`
//! candidate tokens, weighted Zipf(alpha). Candidate identities map
//! log-uniformly onto the vocabulary (`P(tok) ~ 1/tok`), so the stream
//! has (a) learnable structure (conditional entropy ~= log(branching)
//! nats scaled by the Zipf skew — a transformer's loss drops well below
//! the unigram entropy), and (b) a genuinely heavy-tailed unigram
//! distribution like natural text — which is also what makes short
//! training runs (the e2e host-train CI gate) show a fast, robust loss
//! drop from the ln(vocab) floor toward the unigram entropy. Different
//! seeds give disjoint "datasets": the WikiText/C4/Pile eval splits are
//! three held-out seeds with slightly different parameters.

use crate::util::rng::{Rng, ZipfTable};

#[derive(Debug, Clone)]
pub struct CorpusSpec {
    pub vocab: usize,
    /// Candidate fan-out per context (entropy knob).
    pub branching: usize,
    /// Zipf exponent over the candidate ranks.
    pub alpha: f64,
    pub seed: u64,
}

impl CorpusSpec {
    pub fn pretrain(vocab: usize, seed: u64) -> Self {
        CorpusSpec { vocab, branching: 8, alpha: 1.1, seed }
    }

    /// Eval-split flavours (paper Table 2: WikiText-103 / C4 / Pile).
    pub fn eval_split(vocab: usize, name: &str) -> Self {
        match name {
            "wikitext" => CorpusSpec { vocab, branching: 8, alpha: 1.1, seed: 0x5717 },
            "c4" => CorpusSpec { vocab, branching: 12, alpha: 1.0, seed: 0xC4 },
            "pile" => CorpusSpec { vocab, branching: 16, alpha: 0.9, seed: 0x9113 },
            _ => CorpusSpec::pretrain(vocab, 0xE7A1),
        }
    }
}

/// Streaming token generator over the synthetic language.
pub struct SyntheticCorpus {
    spec: CorpusSpec,
    zipf: ZipfTable,
    rng: Rng,
    prev2: u32,
    prev1: u32,
}

impl SyntheticCorpus {
    pub fn new(spec: CorpusSpec) -> Self {
        let zipf = ZipfTable::new(spec.branching, spec.alpha);
        let rng = Rng::new(spec.seed).fork(0xDA7A);
        SyntheticCorpus { spec, zipf, rng, prev2: 1, prev1: 2 }
    }

    /// Candidate token for (context, rank) — pure hash, no tables.
    /// The hash acts as a uniform u in [0, 1) mapped log-uniformly onto
    /// [1, vocab): `tok = floor((vocab-1)^u)`, i.e. `P(tok) ~ 1/tok` —
    /// a Zipf(1)-shaped unigram like natural text. Token 0 stays
    /// reserved as padding/BOS.
    fn candidate(&self, rank: usize) -> u32 {
        let mut h = (self.prev2 as u64) << 32 | self.prev1 as u64;
        h ^= (rank as u64).wrapping_mul(0x9E3779B97F4A7C15);
        h = h.wrapping_mul(0xBF58476D1CE4E5B9);
        h ^= h >> 31;
        h = h.wrapping_mul(0x94D049BB133111EB);
        h ^= h >> 29;
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let span = (self.spec.vocab - 1) as f64;
        (span.powf(u) as u32).clamp(1, self.spec.vocab as u32 - 1)
    }

    pub fn next_token(&mut self) -> u32 {
        let rank = self.rng.zipf(&self.zipf);
        let tok = self.candidate(rank);
        self.prev2 = self.prev1;
        self.prev1 = tok;
        tok
    }

    /// Fill a [batch, seq+1] token matrix (the +1 column is the shifted
    /// target, matching the train_step input spec).
    pub fn fill_batch(&mut self, batch: usize, seq_plus_1: usize, out: &mut Vec<i32>) {
        out.clear();
        out.reserve(batch * seq_plus_1);
        for _ in 0..batch {
            for _ in 0..seq_plus_1 {
                out.push(self.next_token() as i32);
            }
        }
    }

    /// Theoretical conditional entropy of the generator in nats (loss
    /// floor for a perfect model of the context distribution).
    pub fn conditional_entropy(&self) -> f64 {
        // Zipf over `branching` candidates: H = -sum p ln p
        let n = self.spec.branching;
        let w: Vec<f64> = (1..=n).map(|k| (k as f64).powf(-self.spec.alpha)).collect();
        let z: f64 = w.iter().sum();
        -w.iter().map(|x| (x / z) * (x / z).ln()).sum::<f64>()
    }

    pub fn vocab(&self) -> usize {
        self.spec.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let spec = CorpusSpec::pretrain(1024, 7);
        let mut a = SyntheticCorpus::new(spec.clone());
        let mut b = SyntheticCorpus::new(spec);
        for _ in 0..200 {
            assert_eq!(a.next_token(), b.next_token());
        }
    }

    #[test]
    fn tokens_in_range_and_nonzero() {
        let mut c = SyntheticCorpus::new(CorpusSpec::pretrain(256, 1));
        for _ in 0..1000 {
            let t = c.next_token();
            assert!(t >= 1 && t < 256);
        }
    }

    #[test]
    fn stream_is_predictable_from_context() {
        // given the same 2-token context, the candidate set is identical;
        // verify the next-token distribution is concentrated (learnable)
        let mut c = SyntheticCorpus::new(CorpusSpec::pretrain(4096, 3));
        // drive to a fixed context
        c.prev2 = 10;
        c.prev1 = 20;
        let cands: Vec<u32> = (0..8).map(|r| c.candidate(r)).collect();
        for _ in 0..100 {
            c.prev2 = 10;
            c.prev1 = 20;
            let t = c.next_token();
            assert!(cands.contains(&t));
        }
    }

    #[test]
    fn entropy_well_below_unigram() {
        let c = SyntheticCorpus::new(CorpusSpec::pretrain(4096, 5));
        let h = c.conditional_entropy();
        assert!(h < (4096f64).ln() / 2.0, "H={h}");
        assert!(h > 0.5);
    }

    #[test]
    fn unigram_is_heavy_tailed() {
        // The candidate map is log-uniform over token ids (P ~ 1/tok):
        // the head must carry a large share of the mass and the unigram
        // entropy must sit well below ln(vocab) — the fast-learnable
        // signal the e2e host-train CI gate relies on.
        let mut c = SyntheticCorpus::new(CorpusSpec::pretrain(256, 9));
        let n = 20_000usize;
        let mut counts = [0u32; 256];
        for _ in 0..n {
            counts[c.next_token() as usize] += 1;
        }
        let head: u32 = counts[..16].iter().sum();
        assert!(head as f64 / n as f64 > 0.3, "head-16 mass only {head}/{n}");
        let entropy: f64 = counts
            .iter()
            .filter(|&&x| x > 0)
            .map(|&x| {
                let p = x as f64 / n as f64;
                -p * p.ln()
            })
            .sum();
        assert!(entropy < 5.0, "unigram entropy {entropy:.2} not below ln(256)=5.55");
        assert!(entropy > 3.0, "unigram entropy {entropy:.2} degenerately low");
    }

    #[test]
    fn eval_splits_differ() {
        let mut w = SyntheticCorpus::new(CorpusSpec::eval_split(1024, "wikitext"));
        let mut p = SyntheticCorpus::new(CorpusSpec::eval_split(1024, "pile"));
        let ws: Vec<u32> = (0..50).map(|_| w.next_token()).collect();
        let ps: Vec<u32> = (0..50).map(|_| p.next_token()).collect();
        assert_ne!(ws, ps);
    }

    #[test]
    fn batch_fill_shape() {
        let mut c = SyntheticCorpus::new(CorpusSpec::pretrain(512, 2));
        let mut buf = Vec::new();
        c.fill_batch(4, 65, &mut buf);
        assert_eq!(buf.len(), 4 * 65);
    }
}
