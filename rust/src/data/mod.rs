//! Data substrate: synthetic pretraining corpus (Dolma stand-in),
//! byte-level tokenizer for real text, arithmetic-reasoning task
//! generator (MAmmoTH/GSM8K/NumGLUE stand-ins), and batching.

pub mod dataset;
pub mod synth;
pub mod tasks;
pub mod tokenizer;

pub use dataset::{Batch, BatchSource, EvalShard, TaskMixSource};
pub use synth::{CorpusSpec, SyntheticCorpus};
pub use tasks::{TaskGenerator, TaskKind};
pub use tokenizer::ByteTokenizer;
