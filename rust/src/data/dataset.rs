//! Batching: a uniform [batch, seq+1] i32 token-matrix interface over
//! the synthetic corpus and the task generator, plus deterministic
//! held-out shards for evaluation.

use super::synth::{CorpusSpec, SyntheticCorpus};
use super::tasks::{TaskGenerator, TaskKind};

/// One training batch, row-major [batch, seq_plus_1].
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub batch: usize,
    pub seq_plus_1: usize,
}

/// Anything that yields token batches.
pub trait BatchSource {
    fn next_batch(&mut self, batch: usize, seq_plus_1: usize) -> Batch;
    fn name(&self) -> String;
}

impl BatchSource for SyntheticCorpus {
    fn next_batch(&mut self, batch: usize, seq_plus_1: usize) -> Batch {
        let mut tokens = Vec::new();
        self.fill_batch(batch, seq_plus_1, &mut tokens);
        Batch { tokens, batch, seq_plus_1 }
    }

    fn name(&self) -> String {
        "synthetic".into()
    }
}

/// Task source mixing the three families round-robin (like a curriculum
/// over MAmmoTH's task mixture).
pub struct TaskMixSource {
    gens: Vec<TaskGenerator>,
    next: usize,
}

impl TaskMixSource {
    pub fn new(seed: u64) -> Self {
        TaskMixSource {
            gens: TaskKind::ALL.iter().map(|&k| TaskGenerator::new(k, seed)).collect(),
            next: 0,
        }
    }
}

impl BatchSource for TaskMixSource {
    fn next_batch(&mut self, batch: usize, seq_plus_1: usize) -> Batch {
        let mut tokens = Vec::with_capacity(batch * seq_plus_1);
        for _ in 0..batch {
            let i = self.next;
            self.next = (self.next + 1) % self.gens.len();
            tokens.extend(self.gens[i].training_sequence(seq_plus_1));
        }
        Batch { tokens, batch, seq_plus_1 }
    }

    fn name(&self) -> String {
        "math-tasks".into()
    }
}

/// Deterministic held-out eval shard: `n_batches` pregenerated batches
/// from a seed disjoint from training.
pub struct EvalShard {
    pub name: String,
    pub batches: Vec<Batch>,
}

impl EvalShard {
    pub fn synthetic(split: &str, vocab: usize, n_batches: usize, batch: usize, seq_plus_1: usize) -> Self {
        let mut corpus = SyntheticCorpus::new(CorpusSpec::eval_split(vocab, split));
        let batches = (0..n_batches).map(|_| corpus.next_batch(batch, seq_plus_1)).collect();
        EvalShard { name: split.to_string(), batches }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_mix_covers_all_kinds() {
        let mut src = TaskMixSource::new(1);
        let b = src.next_batch(6, 65);
        assert_eq!(b.tokens.len(), 6 * 65);
        assert!(b.tokens.iter().all(|&t| (0..32).contains(&t)));
    }

    #[test]
    fn eval_shard_is_reproducible() {
        let a = EvalShard::synthetic("c4", 512, 2, 2, 17);
        let b = EvalShard::synthetic("c4", 512, 2, 2, 17);
        assert_eq!(a.batches[1].tokens, b.batches[1].tokens);
        let c = EvalShard::synthetic("pile", 512, 2, 2, 17);
        assert_ne!(a.batches[0].tokens, c.batches[0].tokens);
    }
}
