//! Perplexity evaluation over held-out shards (the WikiText/C4/Pile
//! stand-ins; paper Table 2 "Model Performance (PPL)").

use anyhow::Result;
use xla::Literal;

use crate::coordinator::TrainState;
use crate::data::EvalShard;
use crate::runtime::literal::{lit_i32, scalar_f32};
use crate::runtime::Runtime;

/// Perplexity of `state`'s model over `shard`.
pub fn eval_perplexity(rt: &Runtime, state: &TrainState, shard: &EvalShard) -> Result<f64> {
    let eval = rt.program("eval_step")?;
    let man = &rt.manifest;
    let (b, s) = (man.model.batch, man.model.seq);
    let mut nll = 0f64;
    let mut count = 0f64;
    for batch in &shard.batches {
        let tokens = lit_i32(&[b, s + 1], &batch.tokens)?;
        let mut inputs: Vec<&Literal> = state.params.iter().collect();
        inputs.push(&tokens);
        let outs = eval.call(&inputs)?;
        nll += scalar_f32(&outs[0])? as f64;
        count += scalar_f32(&outs[1])? as f64;
    }
    Ok((nll / count.max(1.0)).exp())
}

/// Standard three-split evaluation (paper Table 2 columns).
pub fn eval_three_splits(
    rt: &Runtime,
    state: &TrainState,
    n_batches: usize,
) -> Result<Vec<(String, f64)>> {
    let man = &rt.manifest;
    let (b, s, v) = (man.model.batch, man.model.seq, man.model.vocab);
    let mut out = Vec::new();
    for split in ["wikitext", "c4", "pile"] {
        let shard = EvalShard::synthetic(split, v, n_batches, b, s + 1);
        out.push((split.to_string(), eval_perplexity(rt, state, &shard)?));
    }
    Ok(out)
}
