//! Exact-match accuracy on the arithmetic-reasoning tasks via greedy
//! decoding through the `logits_last` artifact (the GSM8K/Mathematics/
//! NumGLUE stand-in metric; paper Tables 3/4/11).

use anyhow::Result;
use xla::Literal;

use crate::coordinator::TrainState;
use crate::data::tasks::{parse_answer, Problem, TaskGenerator, TaskKind, EOS, PAD};
use crate::runtime::literal::{lit_i32, to_f32};
use crate::runtime::Runtime;

/// Maximum answer tokens to decode (answers are <= 4 digits + sign).
const MAX_DECODE: usize = 6;

/// Greedy-decode answers for a batch of problems and score exact match.
pub fn eval_task_accuracy(
    rt: &Runtime,
    state: &TrainState,
    kind: TaskKind,
    n_problems: usize,
    seed: u64,
) -> Result<f64> {
    let man = &rt.manifest;
    let (b, s, vocab) = (man.model.batch, man.model.seq, man.model.vocab);
    let logits_prog = rt.program("logits_last")?;
    // Held-out generator: offset seed stream from training.
    let mut gen = TaskGenerator::new(kind, seed ^ 0x5EED_EA1u64);
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut problems: Vec<Problem> = Vec::new();
    while total + problems.len() < n_problems {
        problems.push(gen.next_problem());
        if problems.len() == b {
            correct += decode_batch(rt, state, &logits_prog, &problems, b, s, vocab)?;
            total += b;
            problems.clear();
        }
    }
    if !problems.is_empty() {
        while problems.len() < b {
            problems.push(problems[0].clone()); // pad batch with repeats
        }
        let extra = n_problems - total;
        let scored = decode_batch_partial(rt, state, &logits_prog, &problems, b, s, vocab, extra)?;
        correct += scored;
        total += extra;
    }
    Ok(correct as f64 / total.max(1) as f64)
}

fn decode_batch(
    rt: &Runtime,
    state: &TrainState,
    prog: &std::sync::Arc<crate::runtime::Program>,
    problems: &[Problem],
    b: usize,
    s: usize,
    vocab: usize,
) -> Result<usize> {
    decode_batch_partial(rt, state, prog, problems, b, s, vocab, problems.len())
}

/// Decode a full batch but only score the first `count` rows.
fn decode_batch_partial(
    _rt: &Runtime,
    state: &TrainState,
    prog: &std::sync::Arc<crate::runtime::Program>,
    problems: &[Problem],
    b: usize,
    s: usize,
    vocab: usize,
    count: usize,
) -> Result<usize> {
    // Left-padded rolling windows of length s, prompt at the right edge.
    let mut rows: Vec<Vec<i32>> = problems
        .iter()
        .map(|p| {
            let mut row = vec![PAD; s];
            let take = p.prompt.len().min(s);
            row[s - take..].copy_from_slice(&p.prompt[p.prompt.len() - take..]);
            row
        })
        .collect();
    let mut answers: Vec<Vec<i32>> = vec![Vec::new(); b];
    let mut done = vec![false; b];
    for _ in 0..MAX_DECODE {
        let flat: Vec<i32> = rows.iter().flatten().copied().collect();
        let tokens = lit_i32(&[b, s], &flat)?;
        let mut inputs: Vec<&Literal> = state.params.iter().collect();
        inputs.push(&tokens);
        let outs = prog.call(&inputs)?;
        let logits = to_f32(&outs[0])?; // [b, vocab]
        for r in 0..b {
            if done[r] {
                continue;
            }
            let row_logits = &logits[r * vocab..(r + 1) * vocab];
            let tok = argmax(row_logits) as i32;
            if tok == EOS {
                done[r] = true;
                continue;
            }
            answers[r].push(tok);
            rows[r].remove(0);
            rows[r].push(tok);
        }
        if done.iter().all(|&d| d) {
            break;
        }
    }
    let mut correct = 0;
    for r in 0..count {
        let want = parse_answer(&problems[r].answer);
        let got = parse_answer(&answers[r]);
        if want.is_some() && want == got {
            correct += 1;
        }
    }
    Ok(correct)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
