//! Evaluation: perplexity over held-out synthetic splits (paper Table 2)
//! and exact-match accuracy on the arithmetic-reasoning tasks (Tables
//! 3/4/11).

pub mod accuracy;
pub mod perplexity;

pub use accuracy::eval_task_accuracy;
pub use perplexity::eval_perplexity;
