//! PJRT runtime: loads the AOT artifacts (`artifacts/<config>/*.hlo.txt`
//! + `manifest.json`) and executes them on the PJRT CPU client. This is
//! the only boundary between the Rust coordinator and the JAX/Pallas
//! compute stack — and Python is never involved at run time.

pub mod artifact;
pub mod literal;
pub mod program;

pub use artifact::{DType, IoSpec, Manifest, ModelDims, ProgramSpec};
pub use program::{Program, Runtime};
