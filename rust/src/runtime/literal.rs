//! Host <-> XLA literal marshalling helpers.

use anyhow::{bail, Result};
use xla::{ElementType, Literal};

use super::artifact::{DType, IoSpec};

fn as_bytes<T>(v: &[T]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

/// Build an f32 literal of `shape` from row-major data.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::F32, shape, as_bytes(data))?)
}

/// Build an i32 literal of `shape` from row-major data.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, shape, as_bytes(data))?)
}

/// Build an i8 literal of `shape` (E8M0 exponents).
pub fn lit_i8(shape: &[usize], data: &[i8]) -> Result<Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), data.len());
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S8, shape, as_bytes(data))?)
}

/// Scalar literals (rank-0).
pub fn lit_scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

pub fn lit_scalar_i32(v: i32) -> Literal {
    Literal::scalar(v)
}

/// Zero-filled literal matching `spec` (optimizer-state init).
pub fn lit_zeros(spec: &IoSpec) -> Result<Literal> {
    let ty = element_type(spec.dtype);
    Ok(Literal::create_from_shape(ty.primitive_type(), &spec.shape))
}

pub fn element_type(dt: DType) -> ElementType {
    match dt {
        DType::F32 => ElementType::F32,
        DType::I32 => ElementType::S32,
        DType::I8 => ElementType::S8,
        DType::U32 => ElementType::U32,
    }
}

/// Download a literal's contents as f32 (must be an F32 literal).
pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn to_i32(lit: &Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

pub fn to_i8(lit: &Literal) -> Result<Vec<i8>> {
    Ok(lit.to_vec::<i8>()?)
}

/// First element of a rank-0/any f32 literal (loss/gnorm outputs).
pub fn scalar_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Validate a literal against a spec (dtype + element count).
pub fn check_matches(lit: &Literal, spec: &IoSpec) -> Result<()> {
    let n = lit.element_count();
    if n != spec.elems() {
        bail!("literal for {:?} has {} elements, spec wants {}", spec.name, n, spec.elems());
    }
    let ty = lit.ty()?;
    if ty != element_type(spec.dtype) {
        bail!("literal for {:?} has type {:?}, spec wants {:?}", spec.name, ty, spec.dtype);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, -2.5, 3.25, 0.0];
        let lit = lit_f32(&[2, 2], &data).unwrap();
        assert_eq!(to_f32(&lit).unwrap(), data);
        assert_eq!(lit.element_count(), 4);
    }

    #[test]
    fn i32_and_i8_roundtrip() {
        let ints = vec![1i32, -7, 42];
        assert_eq!(to_i32(&lit_i32(&[3], &ints).unwrap()).unwrap(), ints);
        let bytes = vec![-3i8, 0, 7];
        assert_eq!(to_i8(&lit_i8(&[3], &bytes).unwrap()).unwrap(), bytes);
    }

    #[test]
    fn zeros_matches_spec() {
        let spec = IoSpec { name: "m".into(), dtype: DType::F32, shape: vec![3, 5] };
        let z = lit_zeros(&spec).unwrap();
        assert_eq!(to_f32(&z).unwrap(), vec![0.0; 15]);
        check_matches(&z, &spec).unwrap();
    }

    #[test]
    fn check_rejects_mismatch() {
        let spec = IoSpec { name: "x".into(), dtype: DType::F32, shape: vec![4] };
        let lit = lit_i32(&[4], &[0, 1, 2, 3]).unwrap();
        assert!(check_matches(&lit, &spec).is_err());
    }

    #[test]
    fn scalars() {
        assert_eq!(scalar_f32(&lit_scalar_f32(2.5)).unwrap(), 2.5);
        assert_eq!(lit_scalar_i32(7).get_first_element::<i32>().unwrap(), 7);
    }
}
