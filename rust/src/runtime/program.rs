//! Program loading and execution on the PJRT CPU client.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifact::{Manifest, ProgramSpec};
use super::literal::check_matches;

/// A compiled artifact program bound to its IO contract.
pub struct Program {
    pub spec: ProgramSpec,
    exe: PjRtLoadedExecutable,
    /// Keep-mask over manifest inputs: XLA dead-code-eliminates entry
    /// parameters a mode does not consume (e.g. `w_scales` in the bf16
    /// train step); this mask — derived from the HLO text's
    /// `entry_computation_layout` — says which manifest inputs survive.
    pub keep: Vec<bool>,
    /// Cumulative execution stats (hot-path profiling, §Perf).
    pub stats: Mutex<ExecStats>,
}

/// Parse the entry parameter type list from HLO text, e.g.
/// `entry_computation_layout={(f32[2,64]{1,0}, s32[])->(...)}` into
/// `[("f32", [2, 64]), ("s32", [])]`.
pub(crate) fn parse_entry_params(hlo_text: &str) -> Result<Vec<(String, Vec<usize>)>> {
    let start = hlo_text
        .find("entry_computation_layout={(")
        .context("no entry_computation_layout in HLO")?
        + "entry_computation_layout={(".len();
    let rest = &hlo_text[start..];
    let end = rest.find(")->").context("malformed entry_computation_layout")?;
    let list = &rest[..end];
    let mut out = Vec::new();
    for tok in list.split(", ") {
        // strip `/*index=N*/` annotations the HLO printer inserts
        let mut tok = tok.trim();
        while let Some(cs) = tok.find("/*") {
            let ce = tok[cs..].find("*/").context("unclosed comment")? + cs + 2;
            if cs == 0 {
                tok = tok[ce..].trim_start();
            } else {
                tok = &tok[..cs];
            }
        }
        if tok.is_empty() {
            continue;
        }
        let (dtype, dims) = match tok.find('[') {
            Some(b) => {
                let close = tok[b..].find(']').context("unclosed dims")? + b;
                let dims: Vec<usize> = tok[b + 1..close]
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| s.trim().parse::<usize>())
                    .collect::<std::result::Result<_, _>>()?;
                (tok[..b].to_string(), dims)
            }
            None => (tok.to_string(), Vec::new()),
        };
        out.push((dtype, dims));
    }
    Ok(out)
}

fn dtype_hlo_name(dt: crate::runtime::artifact::DType) -> &'static str {
    use crate::runtime::artifact::DType as D;
    match dt {
        D::F32 => "f32",
        D::I32 => "s32",
        D::I8 => "s8",
        D::U32 => "u32",
    }
}

/// Compute the keep-mask: greedy in-order alignment of the manifest's
/// input list against the (possibly shorter) entry parameter list.
pub(crate) fn keep_mask(
    spec: &ProgramSpec,
    entry: &[(String, Vec<usize>)],
) -> Result<Vec<bool>> {
    let mut keep = vec![false; spec.inputs.len()];
    let mut j = 0usize;
    for (i, inp) in spec.inputs.iter().enumerate() {
        if j < entry.len()
            && entry[j].0 == dtype_hlo_name(inp.dtype)
            && entry[j].1 == inp.shape
        {
            keep[i] = true;
            j += 1;
        }
    }
    if j != entry.len() {
        bail!(
            "program {}: could not align {} HLO entry params with {} manifest inputs",
            spec.name,
            entry.len(),
            spec.inputs.len()
        );
    }
    Ok(keep)
}

#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub exec_secs: f64,
    pub download_secs: f64,
}

impl Program {
    /// Execute with host literals; returns one literal per manifest output.
    ///
    /// Handles both PJRT result conventions (auto-untupled buffers vs a
    /// single tuple buffer) — xla_extension 0.5.1's CPU client returns a
    /// tuple for jax-lowered `return_tuple=True` programs.
    pub fn call<L: std::borrow::Borrow<Literal>>(&self, inputs: &[L]) -> Result<Vec<Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "program {} expects {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        if cfg!(debug_assertions) {
            for (lit, spec) in inputs.iter().zip(&self.spec.inputs) {
                check_matches(lit.borrow(), spec)
                    .with_context(|| format!("program {} input", self.spec.name))?;
            }
        }
        let t0 = Instant::now();
        // Filter out inputs XLA pruned from the entry signature.
        let bufs = if self.keep.iter().all(|&k| k) {
            self.exe.execute::<L>(inputs)?
        } else {
            let kept: Vec<&Literal> = inputs
                .iter()
                .zip(&self.keep)
                .filter(|(_, &k)| k)
                .map(|(l, _)| l.borrow())
                .collect();
            self.exe.execute::<&Literal>(&kept)?
        };
        let exec = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let outs = &bufs[0];
        let literals: Vec<Literal> = if outs.len() == self.spec.outputs.len() && outs.len() > 1 {
            // PJRT already untupled.
            outs.iter().map(|b| Ok(b.to_literal_sync()?)).collect::<Result<_>>()?
        } else {
            let mut root = outs[0].to_literal_sync()?;
            match root.ty() {
                // Tuple literals report an error for ty(); decompose then.
                Ok(_) if self.spec.outputs.len() == 1 => vec![root],
                _ => root.decompose_tuple()?,
            }
        };
        if literals.len() != self.spec.outputs.len() {
            bail!(
                "program {} returned {} outputs, manifest says {}",
                self.spec.name,
                literals.len(),
                self.spec.outputs.len()
            );
        }
        let mut st = self.stats.lock().unwrap();
        st.calls += 1;
        st.exec_secs += exec;
        st.download_secs += t1.elapsed().as_secs_f64();
        Ok(literals)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

/// The runtime: one PJRT client + a lazily-loaded program cache for one
/// artifact directory.
pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    programs: Mutex<HashMap<String, Arc<Program>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over `artifacts/<config>`.
    pub fn load(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, programs: Mutex::new(HashMap::new()) })
    }

    /// Get (compiling on first use) a program by manifest name.
    pub fn program(&self, name: &str) -> Result<Arc<Program>> {
        if let Some(p) = self.programs.lock().unwrap().get(name) {
            return Ok(p.clone());
        }
        let spec = self.manifest.program(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        // header is enough for the entry layout (first line of the file)
        let text_head: String = {
            use std::io::Read;
            let mut f = std::fs::File::open(&path)?;
            let mut buf = vec![0u8; 64 * 1024];
            let n = f.read(&mut buf)?;
            String::from_utf8_lossy(&buf[..n]).into_owned()
        };
        let entry = parse_entry_params(&text_head)
            .with_context(|| format!("parsing entry layout of {name}"))?;
        let keep = keep_mask(&spec, &entry)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let secs = t0.elapsed().as_secs_f64();
        if secs > 1.0 {
            eprintln!("[runtime] compiled {name} in {secs:.1}s");
        }
        let prog = Arc::new(Program { spec, exe, keep, stats: Mutex::new(ExecStats::default()) });
        self.programs.lock().unwrap().insert(name.to_string(), prog.clone());
        Ok(prog)
    }

    /// Per-program cumulative stats snapshot (profiling reports).
    pub fn all_stats(&self) -> Vec<(String, ExecStats)> {
        self.programs
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.stats()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::{DType, IoSpec};

    fn spec(inputs: Vec<(&str, DType, Vec<usize>)>) -> ProgramSpec {
        ProgramSpec {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            inputs: inputs
                .into_iter()
                .map(|(n, d, s)| IoSpec { name: n.into(), dtype: d, shape: s })
                .collect(),
            outputs: vec![],
        }
    }

    #[test]
    fn parses_entry_layout() {
        let hlo = "HloModule m, entry_computation_layout={(f32[2,64]{1,0}, s32[], s8[4]{0})->(f32[])}\n";
        let e = parse_entry_params(hlo).unwrap();
        assert_eq!(e.len(), 3);
        assert_eq!(e[0], ("f32".into(), vec![2, 64]));
        assert_eq!(e[1], ("s32".into(), vec![]));
        assert_eq!(e[2], ("s8".into(), vec![4]));
    }

    #[test]
    fn parses_index_annotations() {
        let hlo = "HloModule m, entry_computation_layout={(f32[2]{0}, /*index=5*/f32[3]{0}, s32[])->(f32[])}\n";
        let e = parse_entry_params(hlo).unwrap();
        assert_eq!(e.len(), 3);
        assert_eq!(e[1], ("f32".into(), vec![3]));
    }

    #[test]
    fn keep_mask_handles_pruned_tail() {
        let s = spec(vec![
            ("a", DType::F32, vec![2, 64]),
            ("step", DType::I32, vec![]),
            ("w_scales", DType::F32, vec![2, 4]), // pruned by DCE
        ]);
        let entry = vec![("f32".into(), vec![2, 64]), ("s32".into(), vec![])];
        assert_eq!(keep_mask(&s, &entry).unwrap(), vec![true, true, false]);
    }

    #[test]
    fn keep_mask_handles_pruned_middle() {
        let s = spec(vec![
            ("lnf", DType::F32, vec![64]),     // pruned
            ("head", DType::F32, vec![64, 256]), // pruned
            ("tokens", DType::I32, vec![4, 64]),
        ]);
        let entry = vec![("s32".into(), vec![4, 64])];
        assert_eq!(keep_mask(&s, &entry).unwrap(), vec![false, false, true]);
    }

    #[test]
    fn keep_mask_rejects_misalignment() {
        let s = spec(vec![("a", DType::F32, vec![2])]);
        let entry = vec![("f32".into(), vec![3])];
        assert!(keep_mask(&s, &entry).is_err());
    }
}
