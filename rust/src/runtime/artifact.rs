//! Artifact manifest: the L2->L3 calling convention.
//!
//! `manifest.json` (written by `python/compile/aot.py`) records, for each
//! lowered program, the exact flattened order / dtype / shape of inputs
//! and outputs plus the model and optimizer hyperparameters. The runtime
//! trusts this file instead of re-deriving JAX pytree flattening rules.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element dtype of a program input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    I8,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "i8" => DType::I8,
            "u32" => DType::U32,
            _ => bail!("unsupported dtype {s:?} in manifest"),
        })
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 | DType::U32 => 4,
            DType::I8 => 1,
        }
    }
}

/// One input or output tensor spec.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.size_bytes()
    }
}

/// One lowered program's IO contract.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ProgramSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("program {} has no input {name:?}", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("program {} has no output {name:?}", self.name))
    }
}

/// Model dimensions recorded by the AOT pipeline (artifact config).
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab: usize,
    pub dim: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub seq: usize,
    pub batch: usize,
    pub micro: usize,
    pub group: usize,
    pub param_count: usize,
    pub probe_layer: usize,
}

/// AdamW hyperparameters baked into the train-step programs.
#[derive(Debug, Clone, Copy)]
pub struct AdamWDims {
    pub beta1: f64,
    pub beta2: f64,
    pub weight_decay: f64,
    pub grad_clip: f64,
}

/// Parsed manifest for one artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config_name: String,
    pub model: ModelDims,
    pub adamw: AdamWDims,
    pub param_names: Vec<String>,
    pub linear_names: Vec<String>,
    pub programs: BTreeMap<String, ProgramSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let model = j.expect("model")?;
        let usz = |k: &str| -> Result<usize> { model.expect(k)?.as_usize() };
        let model_dims = ModelDims {
            vocab: usz("vocab")?,
            dim: usz("dim")?,
            layers: usz("layers")?,
            heads: usz("heads")?,
            ffn: usz("ffn")?,
            seq: usz("seq")?,
            batch: usz("batch")?,
            micro: usz("micro")?,
            group: usz("group")?,
            param_count: usz("param_count")?,
            probe_layer: usz("probe_layer")?,
        };
        let aw = j.expect("adamw")?;
        let adamw = AdamWDims {
            beta1: aw.expect("beta1")?.as_f64()?,
            beta2: aw.expect("beta2")?.as_f64()?,
            weight_decay: aw.expect("weight_decay")?.as_f64()?,
            grad_clip: aw.expect("grad_clip")?.as_f64()?,
        };

        let parse_names = |key: &str| -> Result<Vec<String>> {
            j.expect(key)?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect()
        };

        let mut programs = BTreeMap::new();
        for (name, p) in j.expect("programs")?.as_obj()? {
            let iospec = |key: &str| -> Result<Vec<IoSpec>> {
                p.expect(key)?
                    .as_arr()?
                    .iter()
                    .map(|io| {
                        Ok(IoSpec {
                            name: io.expect("name")?.as_str()?.to_string(),
                            dtype: DType::parse(io.expect("dtype")?.as_str()?)?,
                            shape: io
                                .expect("shape")?
                                .as_arr()?
                                .iter()
                                .map(|d| d.as_usize())
                                .collect::<Result<_>>()?,
                        })
                    })
                    .collect()
            };
            programs.insert(
                name.clone(),
                ProgramSpec {
                    name: name.clone(),
                    file: p.expect("file")?.as_str()?.to_string(),
                    inputs: iospec("inputs")?,
                    outputs: iospec("outputs")?,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            config_name: j.expect("config_name")?.as_str()?.to_string(),
            model: model_dims,
            adamw,
            param_names: parse_names("param_names")?,
            linear_names: parse_names("linear_names")?,
            programs,
        })
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("artifact dir {:?} has no program {name:?}", self.dir))
    }

    /// Number of quantized linears = layers x linear kinds (w_scales size).
    pub fn n_linears(&self) -> usize {
        self.model.layers * self.linear_names.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::parse("f32").unwrap().size_bytes(), 4);
        assert_eq!(DType::parse("i8").unwrap().size_bytes(), 1);
        assert!(DType::parse("f64").is_err());
    }

    #[test]
    fn iospec_accounting() {
        let s = IoSpec { name: "x".into(), dtype: DType::F32, shape: vec![4, 8] };
        assert_eq!(s.elems(), 32);
        assert_eq!(s.bytes(), 128);
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // Integration-style: exercises the full parse against the tiny
        // artifacts when they exist (make artifacts).
        let dir = std::path::Path::new("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.config_name, "tiny");
        assert_eq!(m.param_names.len(), 9);
        assert_eq!(m.linear_names.len(), 4);
        let ts = m.program("train_step_moss").unwrap();
        assert_eq!(ts.inputs.len(), 31);
        assert_eq!(ts.outputs.len(), 29);
        assert_eq!(ts.inputs[27].name, "tokens");
        assert_eq!(ts.inputs[27].dtype, DType::I32);
    }
}
