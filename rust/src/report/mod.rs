//! Report generators: every table and figure of the paper's evaluation,
//! regenerated from this framework's own runs, written as ASCII tables +
//! CSV under `results/`.

pub mod comm;
pub mod gemm;
pub mod hlo_stats;
pub mod scaling;
pub mod snr;
pub mod training;
pub mod trend;

use anyhow::Result;

use crate::cli::Args;

/// Output directory for generated reports.
pub fn results_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.get_or("results", "results"))
}

/// Write a rendered table (ASCII + CSV) into results/.
pub fn emit(args: &Args, name: &str, table: &crate::util::table::Table) -> Result<()> {
    let dir = results_dir(args);
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{name}.txt")), table.render())?;
    std::fs::write(dir.join(format!("{name}.csv")), table.to_csv())?;
    print!("{}", table.render());
    Ok(())
}

/// Write free-form text (figures) into results/.
pub fn emit_text(args: &Args, name: &str, text: &str) -> Result<()> {
    let dir = results_dir(args);
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(format!("{name}.txt")), text)?;
    print!("{text}");
    Ok(())
}

/// `repro report [--all | --fig1 --tab6 ...]` — regenerate everything
/// that does not need a long training run; training-dependent reports
/// live in `report::training` and the benches.
pub fn run_all(args: &Args) -> Result<()> {
    let all = args.has("all") || args.switches.is_empty();
    if all || args.has("fig1") || args.has("tab6") {
        gemm::run_cli(args)?;
    }
    if all || args.has("tab5") {
        comm::run_cli(args)?;
    }
    if all || args.has("tab7") || args.has("fig8") {
        snr::run_cli(args)?;
    }
    if all || args.has("fig4") {
        scaling::run_cli(args)?;
    }
    if all || args.has("fig5") || args.has("tab2") {
        training::run_pretrain_report(args)?;
    }
    if args.has("tab3") || args.has("tab11") {
        training::run_finetune_report(args)?;
    }
    if args.has("tab4") {
        training::run_table4_report(args)?;
    }
    if args.has("fig7") {
        training::run_longrun_report(args)?;
    }
    Ok(())
}
