//! `repro comm-table`: Table 5 — memory footprint and communication
//! efficiency across BF16 / COAT / MOSS, from the distsim models — plus
//! two *measured* companions driven by live data-parallel host-backend
//! training loops (`backend::dist`): the wire-format byte accounting
//! (Table 5b) and the compute/communication overlap schedule (Table
//! 5c), where the measured hidden/exposed split of the bucketed
//! pipeline is printed next to what the `distsim::overlap` FIFO model
//! predicts from the same measured per-bucket inputs.

use anyhow::{bail, Result};

use crate::backend::DistTrainer;
use crate::cli::Args;
use crate::config::{
    BackendKind, DistSpec, HostSpec, LrSchedule, ModelKind, ShardMode, TrainConfig, WireKind,
};
use crate::distsim::memory::{activation_memory_gb, MemoryScheme, ModelShape};
use crate::distsim::netmodel::{grad_bytes_per_step, NetModel};
use crate::distsim::overlap::{schedule_overlap, table5_overlap};
use crate::events::{fnum, run_start, Event, EventSink};
use crate::util::json::{num, obj, s as jstr, Json};
use crate::util::table::{f, Table};

const LLAMA7B_PARAMS: f64 = 6.74e9;

pub fn table5() -> Table {
    let shape = ModelShape::llama7b_finetune();
    let net = NetModel::h200_nvlink();
    let mut t = Table::new(
        "Table 5 — Memory & communication (simulated 8xH200, LLaMA-2-7B ft)",
        &[
            "scheme",
            "peak act (GB)",
            "allreduce vol (GB/step)",
            "saving",
            "allreduce latency (ms)",
            "overlap %",
        ],
    );
    let bf16_mem = activation_memory_gb(&shape, MemoryScheme::Bf16);
    for scheme in [MemoryScheme::Bf16, MemoryScheme::Coat, MemoryScheme::Moss] {
        let mem = activation_memory_gb(&shape, scheme);
        let bytes = grad_bytes_per_step(LLAMA7B_PARAMS, scheme);
        let vol = bytes / 1e9;
        let lat = net.allreduce_secs(bytes) * 1e3;
        let (ov, ..) = table5_overlap(scheme, LLAMA7B_PARAMS, net);
        t.row(vec![
            scheme.name().into(),
            f(mem, 1),
            f(vol, 2),
            format!("{:.2}x", bf16_mem / mem),
            f(lat, 1),
            f(ov * 100.0, 1),
        ]);
    }
    t
}

/// The one tiny host model every live measurement in this file trains:
/// Table 5b (wire traffic) and Table 5c (bucket overlap) must be
/// measured on the *same* spec, so their numbers describe one model.
fn measured_cfg(workers: usize, steps: u64, dist: DistSpec) -> TrainConfig {
    TrainConfig {
        backend: BackendKind::Host,
        host: HostSpec {
            vocab: 64,
            dim: 32,
            ffn: 64,
            layers: 2,
            seq: 16,
            batch: 2,
            micro: 32,
            microbatches: workers,
            cache_weights: true,
            model: ModelKind::Mlp,
            heads: 2,
        },
        dist,
        steps,
        lr: LrSchedule { peak: 5e-3, warmup_steps: 1, total_steps: steps, final_ratio: 0.1 },
        log_every: 0,
        ..TrainConfig::default()
    }
}

/// Live measurement: train a tiny host model data-parallel under each
/// wire and report the bytes that actually crossed the ring. The
/// `B/elem` column is the executable check on the Table-5 compression
/// model (4.0 for f32, ~1.0 + 1/32 for the MOSS packed wire).
pub fn measured_wire_table(workers: usize, steps: u64, sink: &EventSink) -> Result<Table> {
    let mut t = Table::new(
        &format!(
            "Table 5b — measured allreduce wire traffic ({workers}-worker host backend, \
             {steps} steps)"
        ),
        &["wire", "B/elem", "bytes/step", "grad elems", "allreduce ms/step", "vs f32"],
    );
    let mut f32_bytes_per_step = 0f64;
    for wire in [WireKind::F32, WireKind::Fp8, WireKind::PackedFp8Group] {
        let dist = DistSpec { workers, wire, shard: ShardMode::Scatter, ..DistSpec::default() };
        let mut trainer = DistTrainer::new(measured_cfg(workers, steps, dist))?;
        if sink.active() {
            sink.emit(&run_start(
                "comm-table",
                trainer.cfg.mode.name(),
                comm_spec_json(workers, steps, wire.name(), false),
            ));
            trainer.set_sink(sink.clone());
        }
        trainer.run(steps)?;
        let comm = trainer.comm;
        if sink.active() {
            sink.emit(&Event::RunEnd {
                summary: obj(vec![
                    ("steps", num(trainer.steps_done as f64)),
                    ("wire_bytes_per_elem", fnum(comm.bytes_per_elem())),
                    ("wire_bytes_per_step", fnum(comm.bytes_per_step())),
                ]),
            });
        }
        if wire == WireKind::F32 {
            f32_bytes_per_step = comm.bytes_per_step();
        }
        let saving = if comm.bytes_per_step() > 0.0 {
            f32_bytes_per_step / comm.bytes_per_step()
        } else {
            0.0
        };
        t.row(vec![
            wire.name().into(),
            f(comm.bytes_per_elem(), 3),
            f(comm.bytes_per_step(), 0),
            format!("{}", comm.grad_elems),
            f(comm.allreduce_ms_per_step(), 3),
            format!("{saving:.2}x"),
        ]);
    }
    Ok(t)
}

/// Live overlap measurement (Table 5c): train the bucketed pipeline
/// (`--overlap --zero`, packed wire) and report each bucket's measured
/// emission time, ring occupancy, and wire bytes — then the measured
/// hidden/exposed split next to the `distsim::overlap` FIFO schedule
/// replayed on those same measured per-bucket inputs. The analytic
/// model and the live loop now describe the *same* execution schedule,
/// so the two overlap ratios are directly comparable.
pub fn measured_overlap_table(workers: usize, steps: u64, sink: &EventSink) -> Result<Table> {
    if workers < 2 {
        bail!("need >= 2 workers to overlap communication (got {workers})");
    }
    let dist = DistSpec {
        workers,
        wire: WireKind::PackedFp8Group,
        shard: ShardMode::Scatter,
        overlap: true,
        zero: true,
        bucket_bytes: 0,
    };
    let mut trainer = DistTrainer::new(measured_cfg(workers, steps, dist))?;
    if sink.active() {
        sink.emit(&run_start(
            "comm-table",
            trainer.cfg.mode.name(),
            comm_spec_json(workers, steps, WireKind::PackedFp8Group.name(), true),
        ));
        trainer.set_sink(sink.clone());
    }
    trainer.run(steps)?;
    if sink.active() {
        sink.emit(&Event::RunEnd {
            summary: obj(vec![
                ("steps", num(trainer.steps_done as f64)),
                ("overlap_ratio", fnum(trainer.overlap.overlap_ratio())),
                ("buckets", num(trainer.buckets.len() as f64)),
            ]),
        });
    }
    let mut t = Table::new(
        &format!(
            "Table 5c — measured bucket overlap ({workers}-worker host backend, packed wire, \
             overlap + zero-1, {steps} steps)"
        ),
        &["bucket", "elems", "bytes/step", "ready ms", "ring ms", "overlap %"],
    );
    let ready: Vec<f64> = trainer.buckets.iter().map(|b| b.mean_ready_secs()).collect();
    let comm: Vec<f64> = trainer.buckets.iter().map(|b| b.mean_comm_secs()).collect();
    for (b, agg) in trainer.buckets.iter().enumerate() {
        t.row(vec![
            format!("{b}"),
            format!("{}", agg.elems),
            f(agg.bytes_per_step(), 0),
            f(agg.mean_ready_secs() * 1e3, 3),
            f(agg.mean_comm_secs() * 1e3, 3),
            String::new(),
        ]);
    }
    let measured = trainer.overlap.overlap_ratio();
    let (predicted, ..) =
        schedule_overlap(&ready, &comm, trainer.overlap.backward_secs_per_step());
    t.row(vec![
        "measured (hidden | exposed)".into(),
        String::new(),
        String::new(),
        f(trainer.overlap.hidden_ms_per_step(), 3),
        f(trainer.overlap.exposed_ms_per_step(), 3),
        f(measured * 100.0, 1),
    ]);
    t.row(vec![
        "fifo model (measured inputs)".into(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        f(predicted * 100.0, 1),
    ]);
    Ok(t)
}

/// Spec payload of the comm-table `run_start` events: what made this
/// measured run distinct (wire, world size, overlap on/off).
fn comm_spec_json(workers: usize, steps: u64, wire: &str, overlap: bool) -> Json {
    obj(vec![
        ("backend", jstr("host")),
        ("workers", num(workers as f64)),
        ("steps", num(steps as f64)),
        ("wire", jstr(wire)),
        ("overlap", Json::Bool(overlap)),
    ])
}

pub fn run_cli(args: &Args) -> Result<()> {
    super::emit(args, "table5_memory_comm", &table5())?;
    let workers = args.get_usize("dist-workers", 4)?;
    let steps = args.get_u64("dist-steps", 3)?;
    if workers < 2 {
        // a world-1 ring is a passthrough: nothing crosses the wire, so
        // the measured table would be all zeros — refuse to pretend
        bail!("--dist-workers must be >= 2 to measure wire traffic (got {workers})");
    }
    let sink = EventSink::from_args(args)?;
    super::emit(args, "table5_measured_wire", &measured_wire_table(workers, steps, &sink)?)?;
    let overlap_steps = args.get_u64("overlap-steps", steps.max(8))?;
    super::emit(
        args,
        "table5_measured_overlap",
        &measured_overlap_table(workers, overlap_steps, &sink)?,
    )?;
    if sink.active() {
        let lines = sink.close()?;
        eprintln!("events: wrote {lines} lines to {}", args.get_or("events", "?"));
    }
    Ok(())
}
