//! `repro comm-table`: Table 5 — memory footprint and communication
//! efficiency across BF16 / COAT / MOSS, from the distsim models — plus
//! two *measured* companions driven by live data-parallel host-backend
//! training loops (`backend::dist`): the wire-format byte accounting
//! (Table 5b) and the compute/communication overlap schedule (Table
//! 5c), where the measured hidden/exposed split of the bucketed
//! pipeline is printed next to what the `distsim::overlap` FIFO model
//! predicts from the same measured per-bucket inputs.

use std::io::BufRead;
use std::path::Path;

use anyhow::{bail, Result};

use crate::backend::DistTrainer;
use crate::cli::Args;
use crate::config::{
    BackendKind, DistSpec, HostSpec, LrSchedule, ModelKind, ShardMode, TrainConfig, WireKind,
};
use crate::distsim::memory::{activation_memory_gb, MemoryScheme, ModelShape};
use crate::distsim::netmodel::{fit_netmodel, grad_bytes_per_step, NetModel, NetModelFit};
use crate::distsim::overlap::{schedule_overlap, table5_overlap};
use crate::events::{fnum, run_start, Event, EventReader, EventSink, ReadOutcome};
use crate::util::json::{num, obj, s as jstr, Json};
use crate::util::table::{f, Table};

const LLAMA7B_PARAMS: f64 = 6.74e9;

pub fn table5() -> Table {
    let shape = ModelShape::llama7b_finetune();
    let net = NetModel::h200_nvlink();
    let mut t = Table::new(
        "Table 5 — Memory & communication (simulated 8xH200, LLaMA-2-7B ft)",
        &[
            "scheme",
            "peak act (GB)",
            "allreduce vol (GB/step)",
            "saving",
            "allreduce latency (ms)",
            "overlap %",
        ],
    );
    let bf16_mem = activation_memory_gb(&shape, MemoryScheme::Bf16);
    for scheme in [MemoryScheme::Bf16, MemoryScheme::Coat, MemoryScheme::Moss] {
        let mem = activation_memory_gb(&shape, scheme);
        let bytes = grad_bytes_per_step(LLAMA7B_PARAMS, scheme);
        let vol = bytes / 1e9;
        let lat = net.allreduce_secs(bytes) * 1e3;
        let (ov, ..) = table5_overlap(scheme, LLAMA7B_PARAMS, net);
        t.row(vec![
            scheme.name().into(),
            f(mem, 1),
            f(vol, 2),
            format!("{:.2}x", bf16_mem / mem),
            f(lat, 1),
            f(ov * 100.0, 1),
        ]);
    }
    t
}

/// The one tiny host model every live measurement in this file trains:
/// Table 5b (wire traffic) and Table 5c (bucket overlap) must be
/// measured on the *same* spec, so their numbers describe one model.
fn measured_cfg(workers: usize, steps: u64, dist: DistSpec) -> TrainConfig {
    TrainConfig {
        backend: BackendKind::Host,
        host: HostSpec {
            vocab: 64,
            dim: 32,
            ffn: 64,
            layers: 2,
            seq: 16,
            batch: 2,
            micro: 32,
            microbatches: workers,
            cache_weights: true,
            model: ModelKind::Mlp,
            heads: 2,
        },
        dist,
        steps,
        lr: LrSchedule { peak: 5e-3, warmup_steps: 1, total_steps: steps, final_ratio: 0.1 },
        log_every: 0,
        ..TrainConfig::default()
    }
}

/// Live measurement: train a tiny host model data-parallel under each
/// wire and report the bytes that actually crossed the ring. The
/// `B/elem` column is the executable check on the Table-5 compression
/// model (4.0 for f32, ~1.0 + 1/32 for the MOSS packed wire).
pub fn measured_wire_table(workers: usize, steps: u64, sink: &EventSink) -> Result<Table> {
    let mut t = Table::new(
        &format!(
            "Table 5b — measured allreduce wire traffic ({workers}-worker host backend, \
             {steps} steps)"
        ),
        &["wire", "B/elem", "bytes/step", "grad elems", "allreduce ms/step", "vs f32"],
    );
    let mut f32_bytes_per_step = 0f64;
    for wire in [WireKind::F32, WireKind::Fp8, WireKind::PackedFp8Group] {
        let dist = DistSpec { workers, wire, shard: ShardMode::Scatter, ..DistSpec::default() };
        let mut trainer = DistTrainer::new(measured_cfg(workers, steps, dist))?;
        if sink.active() {
            sink.emit(&run_start(
                "comm-table",
                trainer.cfg.mode.name(),
                comm_spec_json(workers, steps, wire.name(), false),
            ));
            trainer.set_sink(sink.clone());
        }
        trainer.run(steps)?;
        let comm = trainer.comm;
        if sink.active() {
            sink.emit(&Event::RunEnd {
                summary: obj(vec![
                    ("steps", num(trainer.steps_done as f64)),
                    ("wire_bytes_per_elem", fnum(comm.bytes_per_elem())),
                    ("wire_bytes_per_step", fnum(comm.bytes_per_step())),
                ]),
            });
        }
        if wire == WireKind::F32 {
            f32_bytes_per_step = comm.bytes_per_step();
        }
        let saving = if comm.bytes_per_step() > 0.0 {
            f32_bytes_per_step / comm.bytes_per_step()
        } else {
            0.0
        };
        t.row(vec![
            wire.name().into(),
            f(comm.bytes_per_elem(), 3),
            f(comm.bytes_per_step(), 0),
            format!("{}", comm.grad_elems),
            f(comm.allreduce_ms_per_step(), 3),
            format!("{saving:.2}x"),
        ]);
    }
    Ok(t)
}

/// Live overlap measurement (Table 5c): train the bucketed pipeline
/// (`--overlap --zero`, packed wire) and report each bucket's measured
/// emission time, ring occupancy, and wire bytes — then the measured
/// hidden/exposed split next to the `distsim::overlap` FIFO schedule
/// replayed on those same measured per-bucket inputs. The analytic
/// model and the live loop now describe the *same* execution schedule,
/// so the two overlap ratios are directly comparable.
pub fn measured_overlap_table(workers: usize, steps: u64, sink: &EventSink) -> Result<Table> {
    if workers < 2 {
        bail!("need >= 2 workers to overlap communication (got {workers})");
    }
    let dist = DistSpec {
        workers,
        wire: WireKind::PackedFp8Group,
        shard: ShardMode::Scatter,
        overlap: true,
        zero: true,
        ..DistSpec::default()
    };
    let mut trainer = DistTrainer::new(measured_cfg(workers, steps, dist))?;
    if sink.active() {
        sink.emit(&run_start(
            "comm-table",
            trainer.cfg.mode.name(),
            comm_spec_json(workers, steps, WireKind::PackedFp8Group.name(), true),
        ));
        trainer.set_sink(sink.clone());
    }
    trainer.run(steps)?;
    if sink.active() {
        sink.emit(&Event::RunEnd {
            summary: obj(vec![
                ("steps", num(trainer.steps_done as f64)),
                ("overlap_ratio", fnum(trainer.overlap.overlap_ratio())),
                ("buckets", num(trainer.buckets.len() as f64)),
            ]),
        });
    }
    let mut t = Table::new(
        &format!(
            "Table 5c — measured bucket overlap ({workers}-worker host backend, packed wire, \
             overlap + zero-1, {steps} steps)"
        ),
        &["bucket", "elems", "bytes/step", "ready ms", "ring ms", "overlap %"],
    );
    let ready: Vec<f64> = trainer.buckets.iter().map(|b| b.mean_ready_secs()).collect();
    let comm: Vec<f64> = trainer.buckets.iter().map(|b| b.mean_comm_secs()).collect();
    for (b, agg) in trainer.buckets.iter().enumerate() {
        t.row(vec![
            format!("{b}"),
            format!("{}", agg.elems),
            f(agg.bytes_per_step(), 0),
            f(agg.mean_ready_secs() * 1e3, 3),
            f(agg.mean_comm_secs() * 1e3, 3),
            String::new(),
        ]);
    }
    let measured = trainer.overlap.overlap_ratio();
    let (predicted, ..) =
        schedule_overlap(&ready, &comm, trainer.overlap.backward_secs_per_step());
    t.row(vec![
        "measured (hidden | exposed)".into(),
        String::new(),
        String::new(),
        f(trainer.overlap.hidden_ms_per_step(), 3),
        f(trainer.overlap.exposed_ms_per_step(), 3),
        f(measured * 100.0, 1),
    ]);
    t.row(vec![
        "fifo model (measured inputs)".into(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        f(predicted * 100.0, 1),
    ]);
    Ok(t)
}

/// Spec payload of the comm-table `run_start` events: what made this
/// measured run distinct (wire, world size, overlap on/off).
fn comm_spec_json(workers: usize, steps: u64, wire: &str, overlap: bool) -> Json {
    obj(vec![
        ("backend", jstr("host")),
        ("workers", num(workers as f64)),
        ("steps", num(steps as f64)),
        ("wire", jstr(wire)),
        ("overlap", Json::Bool(overlap)),
    ])
}

/// Per-bucket running sums folded from the `comm_bucket` records of a
/// measured `--events` stream.
#[derive(Debug, Clone, Copy, Default)]
struct BucketSums {
    bytes: f64,
    ready_ms: f64,
    ring_ms: f64,
    n: usize,
}

impl BucketSums {
    fn mean_bytes(&self) -> f64 {
        self.bytes / self.n.max(1) as f64
    }
    fn mean_ready_secs(&self) -> f64 {
        self.ready_ms / 1e3 / self.n.max(1) as f64
    }
    fn mean_ring_secs(&self) -> f64 {
        self.ring_ms / 1e3 / self.n.max(1) as f64
    }
}

/// Everything the netmodel fit and the overlap replay need from one
/// measured event stream: the world size the run was measured at (from
/// `run_start`), raw per-record fit samples, per-bucket means, and the
/// measured hidden/exposed totals.
#[derive(Debug, Clone, Default)]
struct CommStream {
    world: Option<usize>,
    /// Raw fit samples `(bytes_on_wire, ring_secs)`, one per record.
    samples: Vec<(f64, f64)>,
    buckets: Vec<BucketSums>,
    hidden_ms: f64,
    exposed_ms: f64,
    malformed: usize,
}

impl CommStream {
    /// Measured per-step overlap ratio: hidden / (hidden + exposed).
    fn measured_ratio(&self) -> f64 {
        let total = self.hidden_ms + self.exposed_ms;
        if total > 0.0 {
            self.hidden_ms / total
        } else {
            0.0
        }
    }
}

fn fold_comm_stream<R: BufRead>(reader: EventReader<R>) -> CommStream {
    let mut st = CommStream::default();
    for outcome in reader {
        match outcome {
            ReadOutcome::Event(Event::RunStart { spec, .. }) => {
                if let Some(Ok(w)) = spec.get("workers").map(Json::as_f64) {
                    st.world = Some(w as usize);
                }
            }
            ReadOutcome::Event(Event::CommBucket {
                bucket,
                bytes,
                ready_ms,
                ring_ms,
                hidden_ms,
                exposed_ms,
                ..
            }) => {
                if st.buckets.len() <= bucket {
                    st.buckets.resize_with(bucket + 1, BucketSums::default);
                }
                let b = &mut st.buckets[bucket];
                b.bytes += bytes as f64;
                b.ready_ms += ready_ms;
                b.ring_ms += ring_ms;
                b.n += 1;
                st.samples.push((bytes as f64, ring_ms / 1e3));
                st.hidden_ms += hidden_ms;
                st.exposed_ms += exposed_ms;
            }
            ReadOutcome::MalformedLine { .. } => st.malformed += 1,
            _ => {}
        }
    }
    st
}

fn read_comm_stream(path: &Path) -> Result<CommStream> {
    let st = fold_comm_stream(EventReader::open(path)?);
    if st.samples.is_empty() {
        bail!(
            "{} holds no comm_bucket events — the stream must come from a \
             pipelined run (--overlap / --zero) with --events",
            path.display()
        );
    }
    Ok(st)
}

/// World size for the fit: an explicit `--world`-style override wins,
/// else the stream's `run_start` spec.
fn fit_world(args: &Args, key: &str, st: &CommStream, path: &Path) -> Result<usize> {
    let world = match args.get(key) {
        Some(_) => args.get_usize(key, 0)?,
        None => match st.world {
            Some(w) => w,
            None => bail!(
                "{} carries no run_start workers field; pass --{key} explicitly",
                path.display()
            ),
        },
    };
    if world < 2 {
        bail!("netmodel needs a world size >= 2 (got {world})");
    }
    Ok(world)
}

fn fit_stream(st: &CommStream, world: usize) -> Result<NetModelFit> {
    match fit_netmodel(&st.samples, world) {
        Some(fit) => Ok(fit),
        None => bail!("no finite comm_bucket sample survived filtering; cannot fit"),
    }
}

fn fit_json(fit: &NetModelFit) -> Json {
    obj(vec![
        ("alpha_secs", fnum(fit.alpha)),
        ("beta_secs_per_byte", fnum(fit.beta)),
        ("world", num(fit.world as f64)),
        ("samples", num(fit.samples as f64)),
        ("r2", fnum(fit.r2)),
    ])
}

/// `repro netmodel --fit EVENTS.jsonl [--world W] [--out fit.json]`:
/// least-squares the topology netmodel's alpha-beta terms from the
/// measured `comm_bucket` records of one event stream.
pub fn run_netmodel_cli(args: &Args) -> Result<()> {
    let path = match args.get("fit") {
        Some(p) => p.to_string(),
        None => bail!("netmodel requires --fit EVENTS.jsonl (a measured --events stream)"),
    };
    let path = Path::new(&path);
    let st = read_comm_stream(path)?;
    let world = fit_world(args, "world", &st, path)?;
    let fit = fit_stream(&st, world)?;
    if st.malformed > 0 {
        eprintln!("netmodel: skipped {} malformed stream line(s)", st.malformed);
    }
    println!(
        "netmodel fit ({} samples over {} buckets, world {}):",
        fit.samples,
        st.buckets.len(),
        fit.world
    );
    println!("  alpha = {:.3e} s/phase", fit.alpha);
    println!("  beta  = {:.3e} s/byte ({:.2} GB/s per link)", fit.beta, 1e-9 / fit.beta.max(1e-300));
    println!("  r2    = {:.4}", fit.r2);
    if let Some(out) = args.get("out") {
        std::fs::write(out, fit_json(&fit).to_string() + "\n")?;
        eprintln!("netmodel: wrote {out}");
    }
    Ok(())
}

/// `comm-table --predict EVENTS.jsonl [--world W --nodes N] [--check]`:
/// fit the alpha-beta netmodel from the stream's measured `comm_bucket`
/// records, then replay the FIFO overlap schedule on the fitted
/// per-bucket ring times — first at the measured shape (the self-check:
/// the fit must reproduce the overlap ratio it was trained on; `--check
/// --tol 0.15` turns that into a hard gate), then at a target `--world
/// W --nodes N` cluster shape we can't run, whose unobservable
/// inter-node link terms are the fitted intra terms scaled by
/// `--alpha-x` / `--beta-x` (default: the H200-cluster ratios 2.5/5).
fn run_predict(args: &Args, path: &Path) -> Result<()> {
    let st = read_comm_stream(path)?;
    let measured_world = fit_world(args, "measured-world", &st, path)?;
    let fit = fit_stream(&st, measured_world)?;
    let world = args.get_usize("world", measured_world)?;
    let nodes = args.get_usize("nodes", 1)?;
    if world < 2 || nodes == 0 || world % nodes != 0 {
        bail!("--world {world} does not divide into --nodes {nodes} equal nodes");
    }
    let alpha_x = args.get_f64("alpha-x", 2.5)?;
    let beta_x = args.get_f64("beta-x", 5.0)?;
    let topo = fit.topo(world, nodes, alpha_x, beta_x);

    let ready: Vec<f64> = st.buckets.iter().map(BucketSums::mean_ready_secs).collect();
    let measured_comm: Vec<f64> = st.buckets.iter().map(BucketSums::mean_ring_secs).collect();
    let fitted_comm: Vec<f64> =
        st.buckets.iter().map(|b| fit.ring_secs(b.mean_bytes())).collect();
    let target_comm: Vec<f64> = st
        .buckets
        .iter()
        .map(|b| topo.allreduce_secs(fit.msg_bytes(b.mean_bytes())))
        .collect();
    // The stream does not record when backward ended, but the last
    // bucket becomes ready at backward's tail — use the latest mean
    // ready time as the compute horizon for every replay.
    let compute_end = ready.iter().cloned().fold(0.0, f64::max);

    let measured = st.measured_ratio();
    let (fit_ratio, ..) = schedule_overlap(&ready, &fitted_comm, compute_end);
    let (replay_ratio, ..) = schedule_overlap(&ready, &measured_comm, compute_end);
    let (target_ratio, ..) = schedule_overlap(&ready, &target_comm, compute_end);

    let mut t = Table::new(
        &format!(
            "Table 5d — netmodel overlap prediction (fit: world {}, r2 {:.3}; \
             target: world {world}, {nodes} node(s))",
            fit.world, fit.r2
        ),
        &["bucket", "bytes/step", "ready ms", "ring ms measured", "ring ms fit", "ring ms target"],
    );
    for (b, agg) in st.buckets.iter().enumerate() {
        t.row(vec![
            format!("{b}"),
            f(agg.mean_bytes(), 0),
            f(agg.mean_ready_secs() * 1e3, 3),
            f(agg.mean_ring_secs() * 1e3, 3),
            f(fitted_comm[b] * 1e3, 3),
            f(target_comm[b] * 1e3, 3),
        ]);
    }
    for (label, ratio) in [
        ("overlap % measured", measured),
        ("overlap % fifo replay (measured times)", replay_ratio),
        ("overlap % fifo replay (fitted times)", fit_ratio),
        ("overlap % predicted at target shape", target_ratio),
    ] {
        t.row(vec![
            label.into(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            f(ratio * 100.0, 1),
        ]);
    }
    super::emit(args, "table5_predicted_overlap", &t)?;
    if let Some(out) = args.get("out") {
        std::fs::write(out, fit_json(&fit).to_string() + "\n")?;
        eprintln!("netmodel: wrote {out}");
    }
    if args.has("check") {
        let tol = args.get_f64("tol", 0.15)?;
        if measured <= 0.0 {
            bail!("--check needs a stream with nonzero hidden+exposed time");
        }
        let rel = (fit_ratio - measured).abs() / measured;
        if rel > tol {
            bail!(
                "netmodel check FAILED: fitted replay predicts overlap {:.1}% vs \
                 measured {:.1}% ({:.1}% off > {:.0}% tolerance)",
                fit_ratio * 100.0,
                measured * 100.0,
                rel * 100.0,
                tol * 100.0
            );
        }
        eprintln!(
            "netmodel check OK: fitted replay {:.1}% vs measured {:.1}% \
             ({:.1}% off, tolerance {:.0}%)",
            fit_ratio * 100.0,
            measured * 100.0,
            rel * 100.0,
            tol * 100.0
        );
    }
    Ok(())
}

pub fn run_cli(args: &Args) -> Result<()> {
    if let Some(path) = args.get("predict") {
        let path = path.to_string();
        return run_predict(args, Path::new(&path));
    }
    super::emit(args, "table5_memory_comm", &table5())?;
    let workers = args.get_usize("dist-workers", 4)?;
    let steps = args.get_u64("dist-steps", 3)?;
    if workers < 2 {
        // a world-1 ring is a passthrough: nothing crosses the wire, so
        // the measured table would be all zeros — refuse to pretend
        bail!("--dist-workers must be >= 2 to measure wire traffic (got {workers})");
    }
    let sink = EventSink::from_args(args)?;
    super::emit(args, "table5_measured_wire", &measured_wire_table(workers, steps, &sink)?)?;
    let overlap_steps = args.get_u64("overlap-steps", steps.max(8))?;
    super::emit(
        args,
        "table5_measured_overlap",
        &measured_overlap_table(workers, overlap_steps, &sink)?,
    )?;
    if sink.active() {
        let lines = sink.close()?;
        eprintln!("events: wrote {lines} lines to {}", args.get_or("events", "?"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A synthetic measured stream: world-4 run_start plus `steps`
    /// repetitions of two buckets whose ring time follows an exact
    /// alpha-beta line `ring = a + b * bytes`.
    fn synthetic_stream(a: f64, b: f64, steps: u64) -> String {
        let mut lines = vec![run_start(
            "comm-table",
            "test",
            obj(vec![("workers", num(4.0)), ("overlap", Json::Bool(true))]),
        )
        .to_json()
        .to_string()];
        for step in 1..=steps {
            for (bucket, bytes) in [(0usize, 40_000u64), (1, 80_000)] {
                let ring_ms = (a + b * bytes as f64) * 1e3;
                lines.push(
                    Event::CommBucket {
                        step,
                        bucket,
                        bytes,
                        ready_ms: 0.2 + bucket as f64 * 0.3,
                        ring_ms,
                        hidden_ms: ring_ms * 0.8,
                        exposed_ms: ring_ms * 0.2,
                    }
                    .to_json()
                    .to_string(),
                );
            }
        }
        lines.push("not json at all".into());
        lines.join("\n") + "\n"
    }

    #[test]
    fn fold_fit_and_replay_recover_the_synthetic_line() {
        let (a, b) = (4e-4, 2e-9);
        let src = synthetic_stream(a, b, 5);
        let st = fold_comm_stream(EventReader::new(src.as_bytes()));
        assert_eq!(st.world, Some(4));
        assert_eq!(st.buckets.len(), 2);
        assert_eq!(st.samples.len(), 10);
        assert_eq!(st.malformed, 1);
        assert!((st.buckets[0].mean_bytes() - 40_000.0).abs() < 1e-9);
        assert!((st.measured_ratio() - 0.8).abs() < 1e-9, "hidden/exposed fold");

        let fit = fit_netmodel(&st.samples, 4).expect("fit");
        assert!(fit.r2 > 0.999, "exact line must fit exactly (r2 {})", fit.r2);
        for bytes in [40_000.0, 80_000.0, 160_000.0] {
            let want = a + b * bytes;
            let got = fit.ring_secs(bytes);
            assert!(
                (got - want).abs() / want < 1e-6,
                "ring_secs({bytes}) = {got}, want {want}"
            );
        }
        // nodes=1 topo replay is the flat fitted line, at any scale ratio
        let topo = fit.topo(4, 1, 2.5, 5.0);
        let flat = topo.allreduce_secs(fit.msg_bytes(80_000.0));
        assert!((flat - fit.ring_secs(80_000.0)).abs() < 1e-12);
        // two nodes over the same fitted terms cost strictly more: part
        // of the message now crosses the scaled-up inter-node link
        let hier = fit.topo(4, 2, 2.5, 5.0).allreduce_secs(fit.msg_bytes(80_000.0));
        assert!(hier > flat, "hier {hier} vs flat {flat}");
    }

    #[test]
    fn predict_replay_matches_measured_ratio_on_clean_data() {
        let src = synthetic_stream(4e-4, 2e-9, 8);
        let st = fold_comm_stream(EventReader::new(src.as_bytes()));
        let fit = fit_netmodel(&st.samples, 4).expect("fit");
        let ready: Vec<f64> = st.buckets.iter().map(BucketSums::mean_ready_secs).collect();
        let fitted: Vec<f64> = st.buckets.iter().map(|b| fit.ring_secs(b.mean_bytes())).collect();
        let measured: Vec<f64> = st.buckets.iter().map(BucketSums::mean_ring_secs).collect();
        let end = ready.iter().cloned().fold(0.0, f64::max);
        let (fit_ratio, ..) = schedule_overlap(&ready, &fitted, end);
        let (replay_ratio, ..) = schedule_overlap(&ready, &measured, end);
        // on an exactly-linear stream the fitted times ARE the measured
        // times, so the two FIFO replays agree to float noise
        assert!(
            (fit_ratio - replay_ratio).abs() < 1e-9,
            "fit {fit_ratio} vs replay {replay_ratio}"
        );
    }
}
