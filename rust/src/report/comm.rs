//! `repro comm-table`: Table 5 — memory footprint and communication
//! efficiency across BF16 / COAT / MOSS, from the distsim models.

use anyhow::Result;

use crate::cli::Args;
use crate::distsim::memory::{activation_memory_gb, MemoryScheme, ModelShape};
use crate::distsim::netmodel::{grad_bytes_per_step, NetModel};
use crate::distsim::overlap::table5_overlap;
use crate::util::table::{f, Table};

const LLAMA7B_PARAMS: f64 = 6.74e9;

pub fn table5() -> Table {
    let shape = ModelShape::llama7b_finetune();
    let net = NetModel::h200_nvlink();
    let mut t = Table::new(
        "Table 5 — Memory & communication (simulated 8xH200, LLaMA-2-7B ft)",
        &[
            "scheme",
            "peak act (GB)",
            "allreduce vol (GB/step)",
            "saving",
            "allreduce latency (ms)",
            "overlap %",
        ],
    );
    let bf16_mem = activation_memory_gb(&shape, MemoryScheme::Bf16);
    for scheme in [MemoryScheme::Bf16, MemoryScheme::Coat, MemoryScheme::Moss] {
        let mem = activation_memory_gb(&shape, scheme);
        let bytes = grad_bytes_per_step(LLAMA7B_PARAMS, scheme);
        let vol = bytes / 1e9;
        let lat = net.allreduce_secs(bytes) * 1e3;
        let (ov, ..) = table5_overlap(scheme, LLAMA7B_PARAMS, net);
        t.row(vec![
            scheme.name().into(),
            f(mem, 1),
            f(vol, 2),
            format!("{:.2}x", bf16_mem / mem),
            f(lat, 1),
            f(ov * 100.0, 1),
        ]);
    }
    t
}

pub fn run_cli(args: &Args) -> Result<()> {
    super::emit(args, "table5_memory_comm", &table5())
}
