//! `repro gemm-table`: Table 6 + Figure 1 from the H800 cost model.

use anyhow::Result;

use crate::cli::Args;
use crate::gemm_sim::machine::MachineModel;
use crate::gemm_sim::tables::{fig1, table2_throughputs, table6};
use crate::util::table::{f, Table};

pub fn run_cli(args: &Args) -> Result<()> {
    let m = MachineModel::h800();
    super::emit(args, "table6_gemm_runtime", &table6(&m))?;
    super::emit(args, "fig1_gemm_comparison", &fig1(&m))?;

    // Table-2 throughput projection (the modeled H800 half; measured CPU
    // numbers come from report::training).
    let mut t = Table::new(
        "Table 2 (throughput projection) — OLMo-7B on 8x(modeled) H800",
        &["scheme", "tokens/s", "vs BF16"],
    );
    let tps = table2_throughputs(&m);
    let bf16 = tps.iter().find(|(s, _)| s.name() == "BF16").unwrap().1;
    for (scheme, tp) in &tps {
        t.row(vec![
            scheme.name().into(),
            f(*tp, 0),
            format!("{:+.1}%", (tp / bf16 - 1.0) * 100.0),
        ]);
    }
    super::emit(args, "table2_throughput_projection", &t)?;
    Ok(())
}
