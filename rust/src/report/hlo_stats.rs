//! `repro hlo-stats`: artifact inventory + HLO op statistics — the L2
//! structural profiling used in the §Perf pass (checks that the lowered
//! graphs contain the expected op mix: one dot per quantized matmul per
//! direction, no duplicated quantization subgraphs after CSE).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::cli::Args;
use crate::runtime::artifact::Manifest;
use crate::util::table::Table;

/// Count HLO instructions by opcode in one artifact file.
pub fn op_histogram(path: &Path) -> Result<BTreeMap<String, usize>> {
    let text = std::fs::read_to_string(path)?;
    let mut h: BTreeMap<String, usize> = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim_start();
        // instruction lines look like: `%name = type[
        // shape]{layout} opcode(args...)`
        let Some(eq) = line.find(" = ") else { continue };
        let rest = &line[eq + 3..];
        // skip the type/shape to the opcode token
        let Some(sp) = rest.find(' ') else { continue };
        let op = rest[sp + 1..].split('(').next().unwrap_or("").trim();
        if op.is_empty() || !op.chars().all(|c| c.is_ascii_alphanumeric() || c == '-') {
            continue;
        }
        *h.entry(op.to_string()).or_default() += 1;
    }
    Ok(h)
}

/// Summary row per program: file size, instruction count, dots, converts,
/// while-loops (pallas grids), custom-calls (should be zero on CPU).
pub fn inventory(man: &Manifest) -> Result<Table> {
    let mut t = Table::new(
        &format!("HLO inventory — artifacts/{}", man.config_name),
        &["program", "KB", "instrs", "dot", "convert", "while", "custom-call"],
    );
    for (name, spec) in &man.programs {
        let path = man.dir.join(&spec.file);
        let kb = std::fs::metadata(&path)?.len() / 1024;
        let h = op_histogram(&path)?;
        let total: usize = h.values().sum();
        let g = |k: &str| h.get(k).copied().unwrap_or(0).to_string();
        t.row(vec![
            name.clone(),
            kb.to_string(),
            total.to_string(),
            g("dot"),
            g("convert"),
            g("while"),
            g("custom-call"),
        ]);
    }
    Ok(t)
}

pub fn run_cli(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"))
        .join(args.get_or("config", "tiny"));
    let man = Manifest::load(&dir)?;
    super::emit(args, &format!("hlo_stats_{}", man.config_name), &inventory(&man)?)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_parses_hlo_syntax() {
        let tmp = std::env::temp_dir().join("moss_hlo_stats_test.txt");
        std::fs::write(
            &tmp,
            "HloModule m\nENTRY e {\n  %a = f32[2,2]{1,0} parameter(0)\n  \
             %d = f32[2,2]{1,0} dot(%a, %a), lhs_contracting_dims={1}\n  \
             %c = f8e4m3fn[2,2]{1,0} convert(%d)\n}\n",
        )
        .unwrap();
        let h = op_histogram(&tmp).unwrap();
        assert_eq!(h.get("dot"), Some(&1));
        assert_eq!(h.get("convert"), Some(&1));
        assert_eq!(h.get("parameter"), Some(&1));
        let _ = std::fs::remove_file(tmp);
    }

    #[test]
    fn real_artifacts_have_no_custom_calls() {
        let dir = std::path::Path::new("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let man = Manifest::load(dir).unwrap();
        for (name, spec) in &man.programs {
            let h = op_histogram(&man.dir.join(&spec.file)).unwrap();
            assert_eq!(
                h.get("custom-call"),
                None,
                "{name} contains a custom-call (Mosaic leak? must lower interpret=True)"
            );
        }
    }
}
