//! `repro snr`: Table 7 (SNR per layer/scheme, early vs late) and the
//! Figure-8 throughput-vs-fidelity Pareto view.
//!
//! Two data sources:
//! * synthetic activation-like tensors (always available), and
//! * real probes sampled from a short fine-tuning run when artifacts are
//!   present (`--probe` flag; used by the full report).

use anyhow::Result;

use crate::cli::Args;
use crate::gemm_sim::machine::MachineModel;
use crate::gemm_sim::schedule::{kernel_cost, GemmShape, Scheme};
use crate::quant::snr::{table7_snrs, Metric, SchemeSnrs};
use crate::util::rng::Rng;
use crate::util::stats::geomean;
use crate::util::table::{f, Table};

/// Layer flavours the paper samples (Table 7 rows) with the channel-
/// structure spread each tends to exhibit.
const LAYERS: [(&str, f64); 3] = [
    ("Attention Output", 1.8),
    ("FFN Intermediate", 2.2),
    ("LayerNorm Input", 1.5),
];

fn snrs_for(rng: &mut Rng, sigma: f64, rows: usize, cols: usize, metric: Metric) -> SchemeSnrs {
    let x = rng.activation_like(rows, cols, sigma);
    table7_snrs(&x, rows, cols, metric)
}

/// Table 7 on synthetic activation-like tensors; `late` shifts the
/// channel spread up slightly (activations grow heavier-tailed as
/// training progresses — the paper's early/late split).
pub fn table7(metric: Metric, seed: u64) -> Table {
    let metric_name = match metric {
        Metric::Model => "uniform-noise model (paper Eqs. 5-7)",
        Metric::Empirical => "empirical power SNR (paper Eq. 4)",
        Metric::Relative => "per-element relative SNR",
    };
    let mut t = Table::new(
        &format!("Table 7 — SNR (dB), {metric_name}"),
        &["layer", "PT early", "PT late", "PG early", "PG late", "MOSS early", "MOSS late"],
    );
    let mut cols = [Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new()];
    for (i, (name, sigma)) in LAYERS.iter().enumerate() {
        let mut rng = Rng::new(seed ^ (i as u64 * 7919));
        let early = snrs_for(&mut rng, *sigma, 256, 1024, metric);
        let late = snrs_for(&mut rng, *sigma * 1.2, 256, 1024, metric);
        let vals = [
            early.per_tensor,
            late.per_tensor,
            early.per_group,
            late.per_group,
            early.moss,
            late.moss,
        ];
        for (c, v) in cols.iter_mut().zip(vals) {
            c.push(v);
        }
        let mut row = vec![name.to_string()];
        row.extend(vals.iter().map(|v| f(*v, 1)));
        t.row(row);
    }
    let mut row = vec!["Geometric Mean".to_string()];
    row.extend(cols.iter().map(|c| f(geomean(c), 1)));
    t.row(row);
    t
}

/// Figure 8: throughput (tokens/s projection) vs fidelity (model SNR) —
/// the Pareto view combining Table 6 and Table 7.
pub fn fig8(seed: u64) -> Table {
    let m = MachineModel::h800();
    let shape = GemmShape::new(4096, 4096, 8192);
    let mut rng = Rng::new(seed);
    let x = rng.activation_like(256, 1024, 2.0);
    let snrs = table7_snrs(&x, 256, 1024, Metric::Model);
    let thpt = |s: Scheme| shape.flops() / kernel_cost(&m, s, shape).total_secs / 1e12;
    let mut t = Table::new(
        "Figure 8 — Throughput vs quantization fidelity (Pareto view)",
        &["scheme", "eff. TFLOPS (4096x4096x8192)", "SNR dB (model)"],
    );
    t.row(vec!["BF16 (per-tensor exact)".into(), f(thpt(Scheme::Bf16), 0), "inf".into()]);
    t.row(vec!["TE / per-tensor".into(), f(thpt(Scheme::TE), 0), f(snrs.per_tensor, 1)]);
    t.row(vec!["COAT / per-group".into(), f(thpt(Scheme::Coat), 0), f(snrs.per_group, 1)]);
    t.row(vec!["MOSS / two-level".into(), f(thpt(Scheme::Moss), 0), f(snrs.moss, 1)]);
    t
}

pub fn run_cli(args: &Args) -> Result<()> {
    let seed = args.get_u64("seed", 7)?;
    super::emit(args, "table7_snr_model", &table7(Metric::Model, seed))?;
    super::emit(args, "table7_snr_relative", &table7(Metric::Relative, seed))?;
    super::emit(args, "table7_snr_empirical", &table7(Metric::Empirical, seed))?;
    super::emit(args, "fig8_pareto", &fig8(seed))?;
    Ok(())
}

/// Table 7 on REAL probed activations from a training run.
pub fn table7_from_probes(
    probes: &crate::coordinator::probe::ProbeStore,
    metric: Metric,
) -> Option<Table> {
    let (early, late) = probes.early_late();
    if early.is_empty() || late.is_empty() {
        return None;
    }
    let mut t = Table::new(
        "Table 7 (real probes) — SNR (dB)",
        &["layer", "PT early", "PT late", "PG early", "PG late", "MOSS early", "MOSS late"],
    );
    let eval = |samples: &[&crate::coordinator::probe::ProbeSample],
                which: usize|
     -> SchemeSnrs {
        // concatenate a few samples' tensors
        let mut acc = SchemeSnrs { per_tensor: 0.0, per_group: 0.0, moss: 0.0 };
        let mut n = 0f64;
        for s in samples.iter().take(4) {
            let (data, cols): (&[f32], usize) = match which {
                0 => (&s.ln_in, s.dim),
                1 => (&s.attn_out, s.dim),
                _ => (&s.ffn_mid, s.ffn),
            };
            let rows = data.len() / cols;
            let r = table7_snrs(data, rows, cols, metric);
            acc.per_tensor += r.per_tensor;
            acc.per_group += r.per_group;
            acc.moss += r.moss;
            n += 1.0;
        }
        SchemeSnrs {
            per_tensor: acc.per_tensor / n,
            per_group: acc.per_group / n,
            moss: acc.moss / n,
        }
    };
    for (i, name) in ["LayerNorm Input", "Attention Output", "FFN Intermediate"]
        .iter()
        .enumerate()
    {
        let e = eval(&early, i);
        let l = eval(&late, i);
        t.row(vec![
            name.to_string(),
            f(e.per_tensor, 1),
            f(l.per_tensor, 1),
            f(e.per_group, 1),
            f(l.per_group, 1),
            f(e.moss, 1),
            f(l.moss, 1),
        ]);
    }
    Some(t)
}
