//! `repro scale-sim`: Figure 4 — automatic vs JIT scale trajectories,
//! plus Table 1 (scale-computation time vs tensor size) measured on this
//! host's real max-reduction.

use std::time::Instant;

use anyhow::Result;

use crate::cli::Args;
use crate::optim::adamw::{AdamW, AdamWParams};
use crate::scaling::{AutoScaler, JitScaler, ScalingStrategy};
use crate::util::plot::multi_line_plot;
use crate::util::rng::Rng;
use crate::util::stats::absmax;
use crate::util::table::{f, Table};

/// Host-side Fig-4 simulation: run AdamW on a real weight vector with
/// heavy-tailed gradients; record the automatic-scaling prediction vs
/// the true JIT scale each `sample_every` steps.
pub fn fig4_trajectories(
    steps: u64,
    interval: u64,
    lr: f32,
    seed: u64,
) -> (Vec<f64>, Vec<f64>, f64) {
    let n = 4096;
    let mut rng = Rng::new(seed);
    let mut w: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.05).collect();
    let mut opt = AdamW::new(n, AdamWParams::default());
    let mut auto = AutoScaler::new(interval);
    let mut jit = JitScaler::new();
    let mut pred_series = Vec::new();
    let mut jit_series = Vec::new();
    let mut violations = 0u64;
    for t in 1..=steps {
        let scales = {
            let wref = &w;
            let mut src = || Ok(vec![absmax(wref)]);
            auto.scales(t, lr, &mut src).unwrap()
        };
        let jit_scale = {
            let wref = &w;
            let mut src = || Ok(vec![absmax(wref)]);
            jit.scales(t, lr, &mut src).unwrap()[0]
        };
        pred_series.push(scales[0] as f64);
        jit_series.push(jit_scale as f64);
        if scales[0] < jit_scale * (1.0 - 1e-6) {
            violations += 1;
        }
        let g: Vec<f32> = (0..n)
            .map(|_| (rng.normal() * 10f64.powf(rng.range_f64(-2.0, 2.0))) as f32)
            .collect();
        opt.step(&mut w, &g, lr);
    }
    (pred_series, jit_series, violations as f64 / steps as f64)
}

/// Table 1: time to compute per-tensor scaling factors, JIT (real
/// max-reduction over the tensor) vs automatic (O(1) update), on this
/// host. Absolute times differ from the paper's H800 (HBM vs DDR) but
/// the asymmetry — O(N) memory-bound vs O(1) — is the reproduced claim.
pub fn table1() -> Table {
    let sizes: [(usize, usize); 4] =
        [(11008, 16384), (11008, 8192), (4096, 12288), (4096, 4096)];
    let mut t = Table::new(
        "Table 1 — Scale-factor computation time (this host)",
        &["tensor", "JIT scaling (ms)", "automatic scaling (ms)", "ratio"],
    );
    let mut rng = Rng::new(3);
    for (r, c) in sizes {
        let data: Vec<f32> = (0..r * c).map(|_| rng.normal_f32()).collect();
        // JIT: full max-reduction
        let reps = 5;
        let t0 = Instant::now();
        let mut acc = 0f32;
        for _ in 0..reps {
            acc = acc.max(absmax(std::hint::black_box(&data)));
        }
        let jit_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        std::hint::black_box(acc);
        // automatic: s += lr/448 per linear (O(1))
        let mut s = acc / 448.0;
        let t1 = Instant::now();
        let inner = 1000;
        for _ in 0..reps * inner {
            s = std::hint::black_box(s + 2e-4 / 448.0);
        }
        let auto_ms = t1.elapsed().as_secs_f64() * 1e3 / (reps * inner) as f64;
        t.row(vec![
            format!("{r} x {c}"),
            f(jit_ms, 3),
            format!("{auto_ms:.6}"),
            format!("{:.0}x", jit_ms / auto_ms.max(1e-9)),
        ]);
    }
    t
}

pub fn run_cli(args: &Args) -> Result<()> {
    let steps = args.get_u64("steps", 2000)?;
    let interval = args.get_u64("interval", 500)?;
    let (pred, jit, viol) = fig4_trajectories(steps, interval, 1e-3, 42);
    let plot = multi_line_plot(
        &format!("Figure 4 — scale trajectory (interval={interval}, violations={:.2}%)", viol * 100.0),
        &[("automatic (predicted)", &pred), ("jit (true max/448)", &jit)],
        72,
        16,
    );
    super::emit_text(args, "fig4_scale_trajectory", &plot)?;
    super::emit(args, "table1_scaling_time", &table1())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_dominance() {
        let (pred, jit, viol) = fig4_trajectories(400, 100, 1e-3, 1);
        assert_eq!(pred.len(), 400);
        assert_eq!(viol, 0.0, "predicted scale dipped below JIT");
        // curves stay close (paper: "remain relatively close")
        let last_ratio = pred.last().unwrap() / jit.last().unwrap();
        assert!(last_ratio < 3.0, "{last_ratio}");
    }
}
