//! `repro events` — offline consumers of the telemetry stream and the
//! committed perf trajectory.
//!
//! Two modes:
//!
//! * `repro events PATH [--check]` folds one event stream
//!   (`events::reader`) into per-run summaries: event counts, first/
//!   final loss per run, a mode-vs-mode loss table when the stream
//!   holds several runs (e.g. `repro ablate --events`), scale-drift and
//!   comm/serve digests. `--check` turns the summary into a CI gate:
//!   nonzero malformed lines or zero `train_step` events fail.
//! * `repro events --trend [PATH]` renders `bench/trajectory.jsonl`
//!   (appended by `cargo bench -- --append`) as a per-source regression
//!   table and fails when the newest record's throughput drops more
//!   than `--max-drop-pct` (default 20) below the previous record of
//!   the same source.

use std::path::Path;

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::events::reader::{read_all, read_jsonl_objects};
use crate::events::{Event, ReadOutcome};
use crate::util::json::Json;
use crate::util::table::{f, Table};

pub fn run_cli(args: &Args) -> Result<()> {
    if args.has("trend") || args.get("trend").is_some() {
        return run_trend(args);
    }
    let Some(path) = stream_path(args) else {
        bail!(
            "usage: repro events PATH [--check] | repro events --trend [PATH] \
             [--max-drop-pct N]"
        );
    };
    let outcomes = read_all(Path::new(&path))?;
    let summary = summarize(&outcomes);
    print_summary(&path, &summary);
    if args.has("check") || args.get("check").is_some() {
        if !summary.malformed.is_empty() {
            bail!(
                "events --check: {} malformed line(s), first at line {}: {}",
                summary.malformed.len(),
                summary.malformed[0].0,
                summary.malformed[0].1
            );
        }
        if summary.train_steps == 0 && summary.serve_ticks == 0 {
            bail!("events --check: stream has no train_step or serve_tick events");
        }
        println!("events check OK: {} events, 0 malformed", summary.events);
    }
    Ok(())
}

/// The stream path: first positional, tolerating the CLI quirk where
/// `--check PATH` / `--trend PATH` parse as flag values.
fn stream_path(args: &Args) -> Option<String> {
    args.positional
        .first()
        .cloned()
        .or_else(|| args.get("check").map(str::to_string))
        .or_else(|| args.get("trend").map(str::to_string))
}

// ---------------------------------------------------------------------
// Stream summaries
// ---------------------------------------------------------------------

/// Digest of one run (RunStart .. next RunStart) inside a stream.
#[derive(Debug, Default, Clone)]
pub struct RunDigest {
    pub cmd: String,
    pub mode: String,
    pub train_steps: u64,
    pub first_loss: Option<f64>,
    pub final_loss: Option<f64>,
    pub last_tps: f64,
    pub scale_updates: u64,
    pub snaps: u64,
    rel_err_sum: f64,
    rel_err_n: u64,
    pub max_saturation_pct: f64,
    pub comm_events: u64,
    pub comm_bytes: u64,
    pub hidden_ms: f64,
    pub exposed_ms: f64,
    pub serve_ticks: u64,
    pub max_active: usize,
    pub last_tok_s: f64,
    pub last_p99_ms: f64,
    pub evals: u64,
    pub ended: bool,
}

impl RunDigest {
    /// Mean relative scale-prediction error |pred - obs| / obs over the
    /// run's ScaleUpdate events (the §3.2 drift signal).
    pub fn mean_scale_rel_err(&self) -> f64 {
        if self.rel_err_n == 0 {
            return 0.0;
        }
        self.rel_err_sum / self.rel_err_n as f64
    }

    /// Hidden fraction of comm time, recomputed from the CommBucket
    /// events alone (cross-check against `OverlapStats`).
    pub fn overlap_ratio(&self) -> f64 {
        let total = self.hidden_ms + self.exposed_ms;
        if !total.is_finite() || total <= 0.0 {
            return 0.0;
        }
        self.hidden_ms / total
    }
}

/// Whole-stream digest: per-run breakdown plus reader health.
#[derive(Debug, Default, Clone)]
pub struct StreamSummary {
    pub runs: Vec<RunDigest>,
    pub events: u64,
    pub train_steps: u64,
    pub serve_ticks: u64,
    pub unknown: Vec<(usize, String)>,
    pub malformed: Vec<(usize, String)>,
}

pub fn summarize(outcomes: &[ReadOutcome]) -> StreamSummary {
    let mut s = StreamSummary::default();
    for o in outcomes {
        match o {
            ReadOutcome::UnknownKind { lineno, kind, .. } => {
                s.unknown.push((*lineno, kind.clone()));
            }
            ReadOutcome::MalformedLine { lineno, error } => {
                s.malformed.push((*lineno, error.clone()));
            }
            ReadOutcome::Event(ev) => {
                s.events += 1;
                if matches!(ev, Event::RunStart { .. }) || s.runs.is_empty() {
                    // Events before any RunStart fold into an implicit
                    // headerless run (a truncated stream still counts).
                    s.runs.push(RunDigest::default());
                }
                let run = s.runs.last_mut().expect("just ensured a run exists");
                match ev {
                    Event::RunStart { cmd, mode, .. } => {
                        run.cmd.clone_from(cmd);
                        run.mode.clone_from(mode);
                    }
                    Event::TrainStep { loss, tokens_per_sec, .. } => {
                        s.train_steps += 1;
                        run.train_steps += 1;
                        if run.first_loss.is_none() {
                            run.first_loss = Some(*loss);
                        }
                        run.final_loss = Some(*loss);
                        run.last_tps = *tokens_per_sec;
                    }
                    Event::ScaleUpdate {
                        predicted_amax,
                        observed_amax,
                        saturation_pct,
                        snap,
                        ..
                    } => {
                        run.scale_updates += 1;
                        if *snap {
                            run.snaps += 1;
                        }
                        if *observed_amax > 0.0 && predicted_amax.is_finite() {
                            run.rel_err_sum +=
                                (predicted_amax - observed_amax).abs() / observed_amax;
                            run.rel_err_n += 1;
                        }
                        if saturation_pct.is_finite() {
                            run.max_saturation_pct = run.max_saturation_pct.max(*saturation_pct);
                        }
                    }
                    Event::CommBucket { bytes, hidden_ms, exposed_ms, .. } => {
                        run.comm_events += 1;
                        run.comm_bytes += bytes;
                        if hidden_ms.is_finite() {
                            run.hidden_ms += hidden_ms;
                        }
                        if exposed_ms.is_finite() {
                            run.exposed_ms += exposed_ms;
                        }
                    }
                    Event::ServeTick { active, tok_s, p99_ms, .. } => {
                        s.serve_ticks += 1;
                        run.serve_ticks += 1;
                        run.max_active = run.max_active.max(*active);
                        run.last_tok_s = *tok_s;
                        run.last_p99_ms = *p99_ms;
                    }
                    Event::EvalPoint { .. } => run.evals += 1,
                    Event::RunEnd { .. } => run.ended = true,
                }
            }
        }
    }
    s
}

fn print_summary(path: &str, s: &StreamSummary) {
    println!(
        "stream {path}: {} event(s) across {} run(s), {} unknown-kind, {} malformed",
        s.events,
        s.runs.len(),
        s.unknown.len(),
        s.malformed.len()
    );
    for (lineno, kind) in s.unknown.iter().take(5) {
        println!("  unknown kind {kind:?} at line {lineno} (skipped, raw preserved)");
    }
    for (lineno, err) in s.malformed.iter().take(5) {
        println!("  malformed line {lineno}: {err}");
    }

    if !s.runs.is_empty() {
        let mut t = Table::new(
            "runs",
            &["run", "cmd", "mode", "steps", "first loss", "final loss", "tok/s"],
        );
        for (i, r) in s.runs.iter().enumerate() {
            t.row(vec![
                format!("{}{}", i, if r.ended { "" } else { " (truncated)" }),
                r.cmd.clone(),
                r.mode.clone(),
                r.train_steps.to_string(),
                r.first_loss.map_or("-".to_string(), |l| f(l, 4)),
                r.final_loss.map_or("-".to_string(), |l| f(l, 4)),
                f(r.last_tps, 0),
            ]);
        }
        print!("{}", t.render());
    }

    // Mode-vs-mode loss table: meaningful when the stream holds several
    // trained runs (repro ablate --events writes one run per mode).
    let trained: Vec<&RunDigest> = s.runs.iter().filter(|r| r.final_loss.is_some()).collect();
    if trained.len() > 1 {
        let base = trained.iter().find(|r| r.mode == "bf16").copied();
        let mut t = Table::new("mode vs mode (final loss)", &["mode", "final loss", "vs bf16"]);
        for r in &trained {
            let loss = r.final_loss.unwrap_or(f64::NAN);
            let gap = match base.and_then(|b| b.final_loss) {
                Some(b) if r.mode != "bf16" => format!("{:+.4}", loss - b),
                _ => "-".to_string(),
            };
            t.row(vec![r.mode.clone(), f(loss, 4), gap]);
        }
        print!("{}", t.render());
    }

    for (i, r) in s.runs.iter().enumerate() {
        if r.scale_updates > 0 {
            println!(
                "run {i} scale drift: {} updates, {} snaps, mean |pred-obs|/obs {:.4}, \
                 max saturation {:.3}%",
                r.scale_updates,
                r.snaps,
                r.mean_scale_rel_err(),
                r.max_saturation_pct
            );
        }
        if r.comm_events > 0 {
            println!(
                "run {i} comm: {} bucket events, {:.1} KB on wire, overlap ratio {:.2} \
                 (hidden {:.1} ms / exposed {:.1} ms)",
                r.comm_events,
                r.comm_bytes as f64 / 1e3,
                r.overlap_ratio(),
                r.hidden_ms,
                r.exposed_ms
            );
        }
        if r.serve_ticks > 0 {
            println!(
                "run {i} serve: {} ticks, max active {}, last {:.1} tok/s, last p99 {:.1} ms",
                r.serve_ticks, r.max_active, r.last_tok_s, r.last_p99_ms
            );
        }
    }
}

// ---------------------------------------------------------------------
// Perf trajectory (--trend)
// ---------------------------------------------------------------------

/// The regression gate per trajectory source: which field is "the"
/// throughput of that bench.
const GATES: &[(&str, &str)] = &[
    ("host", "host_step_tokens_per_sec"),
    ("serve", "decode_tps_packed"),
];

/// Columns shown per source in the trend table.
const TREND_COLS: &[(&str, &[&str])] = &[
    (
        "host",
        &[
            "host_step_tokens_per_sec",
            "packed_gemm_speedup_512_p50",
            "moss_vs_bf16_host_speedup",
            "wire_packed_bytes_per_elem",
            "overlap_ratio_measured",
        ],
    ),
    ("serve", &["decode_tps_packed", "decode_tps_dequant", "tokens_per_sec", "p99_ms"]),
];

fn run_trend(args: &Args) -> Result<()> {
    let path = stream_path(args).unwrap_or_else(|| "bench/trajectory.jsonl".to_string());
    let max_drop = args.get_f64("max-drop-pct", 20.0)?;
    let p = Path::new(&path);
    if !p.exists() {
        println!(
            "trajectory {path}: missing — no baseline yet (seed it with \
             `cargo bench --bench host_backend -- --append {path}`)"
        );
        return Ok(());
    }
    let (records, bad) = read_jsonl_objects(p)?;
    for (lineno, err) in bad.iter().take(5) {
        println!("  malformed trajectory line {lineno}: {err}");
    }
    if records.is_empty() {
        println!("trajectory {path}: empty — no baseline yet");
        return Ok(());
    }
    println!("trajectory {path}: {} record(s), {} malformed", records.len(), bad.len());

    for (source, cols) in TREND_COLS {
        let rows: Vec<&Json> = records.iter().filter(|r| source_of(r) == *source).collect();
        if rows.is_empty() {
            continue;
        }
        let mut header = vec!["git", "when"];
        header.extend_from_slice(cols);
        let mut t = Table::new(&format!("trend: {source}"), &header);
        for r in &rows {
            let mut cells = vec![
                str_field(r, "git").unwrap_or_else(|| "?".to_string()),
                str_field(r, "unix_secs")
                    .or_else(|| metric(r, "unix_secs").map(|v| format!("{v:.0}")))
                    .unwrap_or_else(|| "?".to_string()),
            ];
            for c in *cols {
                cells.push(metric(r, c).map_or("-".to_string(), |v| f(v, 3)));
            }
            t.row(cells);
        }
        print!("{}", t.render());
    }

    let regs = regressions(&records, max_drop);
    for r in &regs {
        eprintln!("REGRESSION: {r}");
    }
    if !regs.is_empty() {
        bail!("{} perf regression(s) beyond {max_drop:.0}% (see above)", regs.len());
    }
    println!("trend OK: no source dropped more than {max_drop:.0}% vs its previous record");
    Ok(())
}

/// Compare the last two records of each gated source; a drop beyond
/// `max_drop_pct` on the source's throughput metric is a regression.
/// With fewer than two records there is no baseline — never fails.
pub fn regressions(records: &[Json], max_drop_pct: f64) -> Vec<String> {
    let mut out = Vec::new();
    for (source, key) in GATES {
        let vals: Vec<f64> = records
            .iter()
            .filter(|r| source_of(r) == *source)
            .filter_map(|r| metric(r, key))
            .filter(|v| v.is_finite() && *v > 0.0)
            .collect();
        if vals.len() < 2 {
            continue;
        }
        let (prev, last) = (vals[vals.len() - 2], vals[vals.len() - 1]);
        let drop_pct = (1.0 - last / prev) * 100.0;
        if drop_pct > max_drop_pct {
            out.push(format!(
                "{source}.{key}: {last:.1} is {drop_pct:.1}% below previous {prev:.1} \
                 (limit {max_drop_pct:.0}%)"
            ));
        }
    }
    out
}

fn source_of(r: &Json) -> &str {
    r.get("source").and_then(|s| s.as_str().ok()).unwrap_or("")
}

fn metric(r: &Json, key: &str) -> Option<f64> {
    r.get(key).and_then(|v| v.as_f64().ok())
}

fn str_field(r: &Json, key: &str) -> Option<String> {
    r.get(key).and_then(|v| v.as_str().ok()).map(str::to_string)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::{run_start, EventReader};
    use crate::util::json::{num, obj, s as jstr};

    fn stream(lines: &[String]) -> Vec<ReadOutcome> {
        let joined = lines.join("\n");
        EventReader::new(joined.as_bytes()).collect()
    }

    #[test]
    fn summarize_splits_runs_and_digests_losses() {
        let mut lines = Vec::new();
        for (mode, base) in [("bf16", 4.0), ("moss", 4.1)] {
            lines.push(run_start("ablate", mode, obj(vec![("dim", num(32.0))])).to_line());
            for step in 1..=3u64 {
                lines.push(
                    Event::TrainStep {
                        step,
                        loss: base - step as f64 * 0.5,
                        gnorm: 1.0,
                        tokens_per_sec: 1000.0,
                    }
                    .to_line(),
                );
            }
            lines.push(Event::RunEnd { summary: Json::Null }.to_line());
        }
        let s = summarize(&stream(&lines));
        assert_eq!(s.runs.len(), 2);
        assert_eq!(s.train_steps, 6);
        assert!(s.malformed.is_empty() && s.unknown.is_empty());
        assert_eq!(s.runs[0].mode, "bf16");
        assert_eq!(s.runs[0].first_loss, Some(3.5));
        assert_eq!(s.runs[0].final_loss, Some(2.5));
        assert!((s.runs[1].final_loss.unwrap() - 2.6).abs() < 1e-12);
        assert!(s.runs.iter().all(|r| r.ended));
    }

    #[test]
    fn summarize_tolerates_headerless_and_corrupt_streams() {
        let lines = vec![
            Event::TrainStep { step: 1, loss: 2.0, gnorm: 1.0, tokens_per_sec: 10.0 }.to_line(),
            "garbage!".to_string(),
            r#"{"v":1,"kind":"gpu_temp","celsius":70}"#.to_string(),
        ];
        let s = summarize(&stream(&lines));
        assert_eq!(s.runs.len(), 1, "implicit headerless run");
        assert_eq!(s.runs[0].train_steps, 1);
        assert!(!s.runs[0].ended);
        assert_eq!(s.malformed.len(), 1);
        assert_eq!(s.unknown.len(), 1);
    }

    #[test]
    fn summarize_scale_and_comm_digests() {
        let lines = vec![
            run_start("train", "moss", Json::Null).to_line(),
            Event::ScaleUpdate {
                step: 1,
                layer: 0,
                predicted_amax: 1.1,
                observed_amax: 1.0,
                saturation_pct: 0.5,
                snap: true,
            }
            .to_line(),
            Event::CommBucket {
                step: 1,
                bucket: 0,
                bytes: 1000,
                ready_ms: 1.0,
                ring_ms: 4.0,
                hidden_ms: 3.0,
                exposed_ms: 1.0,
            }
            .to_line(),
        ];
        let s = summarize(&stream(&lines));
        let r = &s.runs[0];
        assert_eq!((r.scale_updates, r.snaps), (1, 1));
        assert!((r.mean_scale_rel_err() - 0.1).abs() < 1e-9);
        assert_eq!(r.comm_bytes, 1000);
        assert!((r.overlap_ratio() - 0.75).abs() < 1e-12);
    }

    fn traj(source: &str, key: &str, v: f64) -> Json {
        obj(vec![("source", jstr(source)), (key, num(v))])
    }

    #[test]
    fn regression_gate_fires_only_past_threshold() {
        let key = "host_step_tokens_per_sec";
        // No baseline: one record never regresses.
        assert!(regressions(&[traj("host", key, 100.0)], 20.0).is_empty());
        // 10% drop under a 20% limit: fine.
        let recs = vec![traj("host", key, 100.0), traj("host", key, 90.0)];
        assert!(regressions(&recs, 20.0).is_empty());
        // 30% drop: fires.
        let recs = vec![traj("host", key, 100.0), traj("host", key, 70.0)];
        let regs = regressions(&recs, 20.0);
        assert_eq!(regs.len(), 1);
        assert!(regs[0].contains("host_step_tokens_per_sec"), "{}", regs[0]);
        // Sources gate independently; an improving serve doesn't mask it.
        let recs = vec![
            traj("host", key, 100.0),
            traj("serve", "decode_tps_packed", 50.0),
            traj("host", key, 70.0),
            traj("serve", "decode_tps_packed", 60.0),
        ];
        assert_eq!(regressions(&recs, 20.0).len(), 1);
    }

    #[test]
    fn regression_gate_compares_latest_pair() {
        let key = "decode_tps_packed";
        // Old regression already absorbed; only the newest pair counts.
        let recs = vec![
            traj("serve", key, 100.0),
            traj("serve", key, 40.0),
            traj("serve", key, 41.0),
        ];
        assert!(regressions(&recs, 20.0).is_empty());
    }
}
