//! Training-dependent reports: Fig 5 (pretraining loss curves per
//! numerics mode) + Table 2 (measured throughput), both driven by
//! *live host-backend loops* — zero AOT artifacts — plus the
//! `repro ablate` final-loss table over all four `QuantMode`s.
//! Fig 6/Table 3 (fine-tuning), Table 4 (accuracy parity across
//! sizes), Fig 7 (long-run stability) and Table 7-from-probes still
//! run through the PJRT runtime and need `make artifacts`.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::backend::HostTrainer;
use crate::cli::Args;
use crate::config::{BackendKind, DataKind, LrSchedule, QuantMode, ScalingKind, TrainConfig};
use crate::coordinator::Trainer;
use crate::data::TaskKind;
use crate::events::{fnum, run_start, Event, EventSink};
use crate::quant::snr::Metric;
use crate::runtime::Runtime;
use crate::util::json::{num, obj, s as jstr, Json};
use crate::util::plot::multi_line_plot;
use crate::util::table::{f, Table};

/// The four numerics modes in baseline-first order (bf16 anchors the
/// comparisons, moss is the paper's recipe).
const ABLATION_MODES: [QuantMode; 4] =
    [QuantMode::Bf16, QuantMode::PerTensor, QuantMode::Coat, QuantMode::Moss];

fn base_cfg(args: &Args, steps_default: u64) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    cfg.artifact_config = args.get_or("config", "small").to_string();
    cfg.steps = args.get_u64("steps", steps_default)?;
    cfg.lr.total_steps = cfg.steps;
    cfg.lr.warmup_steps = (cfg.steps / 10).max(5);
    cfg.lr.peak = args.get_f64("lr", 2e-4)?;
    cfg.log_every = args.get_u64("log-every", 50)?;
    cfg.seed = args.get_u64("seed", 0)?;
    Ok(cfg)
}

/// Train one mode to completion on the AOT runtime and return the
/// trainer (the artifact-backed fine-tuning/parity reports).
fn train_mode(rt: &Arc<Runtime>, cfg: &TrainConfig, mode: QuantMode) -> Result<Trainer> {
    let mut c = cfg.clone();
    c.mode = mode;
    if mode == QuantMode::Coat || mode == QuantMode::Bf16 {
        // these modes quantize weights JIT inside the graph (or not at
        // all); the injected scales are unused, skip absmax entirely
        c.scaling = ScalingKind::Auto { interval: u64::MAX };
    }
    let mut tr = Trainer::new(rt.clone(), c)?;
    tr.run(cfg.steps)?;
    Ok(tr)
}

/// Host-backend base config of the mode-comparison flows (`repro
/// report --fig5` and `repro ablate`): shape/step/seed flags applied
/// on top of the default host spec, with the host loop's hot recipe.
fn host_base_cfg(args: &Args, steps_default: u64) -> Result<TrainConfig> {
    let mut cfg = TrainConfig { backend: BackendKind::Host, ..TrainConfig::default() };
    cfg.host = cfg.host.apply_args(args)?;
    cfg.host.validate()?;
    cfg.steps = args.get_u64("steps", steps_default)?;
    cfg.seed = args.get_u64("seed", 0)?;
    cfg.log_every = args.get_u64("log-every", 0)?;
    cfg.lr = LrSchedule {
        peak: args.get_f64("lr", 5e-3)?,
        warmup_steps: (cfg.steps / 10).clamp(1, 20),
        total_steps: cfg.steps.max(1),
        final_ratio: 0.1,
    };
    Ok(cfg)
}

/// Train one numerics mode to completion on the host backend (shared
/// seed/corpus across modes: only `cfg.mode` changes). When `sink` is
/// active, the run is bracketed by run_start/run_end events so a
/// single `--events` stream carries all modes of an ablation.
pub(crate) fn train_host_mode(
    cmd: &str,
    cfg: &TrainConfig,
    mode: QuantMode,
    sink: &EventSink,
) -> Result<HostTrainer> {
    let mut c = cfg.clone();
    c.mode = mode;
    let mut tr = HostTrainer::new(c)?;
    if sink.active() {
        sink.emit(&run_start(cmd, mode.name(), host_spec_json(cfg)));
        tr.set_sink(sink.clone());
    }
    tr.run(cfg.steps)?;
    if sink.active() {
        sink.emit(&Event::RunEnd {
            summary: obj(vec![
                ("steps", num(tr.steps_done as f64)),
                ("final_loss", fnum(tr.history.tail_loss(10))),
                ("tokens_per_sec", fnum(tr.throughput.tokens_per_sec())),
            ]),
        });
    }
    Ok(tr)
}

/// Shape/seed payload for report-driven `run_start` events.
fn host_spec_json(cfg: &TrainConfig) -> Json {
    let spec = cfg.host;
    obj(vec![
        ("backend", jstr("host")),
        ("model", jstr(spec.model.name())),
        ("vocab", num(spec.vocab as f64)),
        ("dim", num(spec.dim as f64)),
        ("ffn", num(spec.ffn as f64)),
        ("layers", num(spec.layers as f64)),
        ("heads", num(spec.heads as f64)),
        ("seq", num(spec.seq as f64)),
        ("batch", num(spec.batch as f64)),
        ("microbatches", num(spec.microbatches as f64)),
        ("steps", num(cfg.steps as f64)),
        ("seed", num(cfg.seed as f64)),
    ])
}

/// Fig 5 + Table 2 (host analog): pretraining loss curves and measured
/// throughput per numerics mode, from live host-backend training —
/// zero AOT artifacts anywhere on the path.
pub fn run_pretrain_report(args: &Args) -> Result<()> {
    let cfg = host_base_cfg(args, 120)?;
    let sink = EventSink::from_args(args)?;
    let mut curves: Vec<(&str, Vec<f64>)> = Vec::new();
    let mut t2 = Table::new(
        "Table 2 (measured, host backend) — pretraining on synthetic corpus",
        &["mode", "tokens/s (CPU)", "vs bf16", "final loss", "gap vs bf16"],
    );
    let mut bf16_tps = 0f64;
    let mut bf16_loss = f64::NAN;
    for mode in ABLATION_MODES {
        let tr = train_host_mode("report", &cfg, mode, &sink)?;
        let tps = tr.throughput.tokens_per_sec();
        let final_loss = tr.history.tail_loss(10);
        if mode == QuantMode::Bf16 {
            bf16_tps = tps;
            bf16_loss = final_loss;
        }
        t2.row(vec![
            mode.name().into(),
            f(tps, 0),
            format!("{:+.1}%", (tps / bf16_tps - 1.0) * 100.0),
            f(final_loss, 4),
            format!("{:+.4}", final_loss - bf16_loss),
        ]);
        curves.push((mode.name(), tr.history.loss_series()));
    }
    let series: Vec<(&str, &[f64])> =
        curves.iter().map(|(n, v)| (*n, v.as_slice())).collect();
    let plot = multi_line_plot("Figure 5 — pretraining loss (host backend)", &series, 72, 16);
    super::emit_text(args, "fig5_pretrain_loss", &plot)?;
    std::fs::write(super::results_dir(args).join("fig5_pretrain_loss.csv"), curves_csv(&curves))?;
    super::emit(args, "table2_measured", &t2)?;
    if sink.active() {
        let lines = sink.close()?;
        eprintln!("events: wrote {lines} lines to {}", args.get_or("events", "?"));
    }
    Ok(())
}

/// CSV of per-mode loss curves: `step,<mode>,<mode>,...` rows from the
/// live trajectories (ragged tails pad with NaN).
fn curves_csv(curves: &[(&str, Vec<f64>)]) -> String {
    let mut csv = String::from("step");
    for (name, _) in curves {
        csv.push(',');
        csv.push_str(name);
    }
    csv.push('\n');
    let steps = curves.iter().map(|(_, c)| c.len()).max().unwrap_or(0);
    for i in 0..steps {
        csv.push_str(&format!("{}", i + 1));
        for (_, c) in curves {
            csv.push_str(&format!(",{}", c.get(i).copied().unwrap_or(f64::NAN)));
        }
        csv.push('\n');
    }
    csv
}

/// The `--sweep-interval` list (`"1,2,4"`), defaulting to the
/// powers-of-two ladder {1, 2, 4, 8, 16} when the switch is bare.
fn sweep_intervals(args: &Args) -> Result<Vec<u64>> {
    let raw = match args.get("sweep-interval") {
        None => return Ok(vec![1, 2, 4, 8, 16]),
        Some(v) => v,
    };
    let mut out = Vec::new();
    for part in raw.split(',') {
        let n: u64 = part.trim().parse().map_err(|_| {
            anyhow!("--sweep-interval expects a comma list of positive integers, got {raw:?}")
        })?;
        if n == 0 {
            bail!("--sweep-interval entries must be >= 1 (interval 0 never anchors)");
        }
        out.push(n);
    }
    Ok(out)
}

/// `repro ablate --sweep-interval [N,N,..]`: hold the MOSS recipe fixed
/// and sweep the automatic-scaling re-anchor interval against the bf16
/// anchor on one shared seed/corpus. The interval is the knob the
/// paper's automatic scaling turns: N=1 re-anchors every step
/// (JIT-like absmax cost), larger N amortize the absmax pass but let
/// the predicted scales drift further between anchors — this table
/// makes the loss cost of that drift measurable per N.
fn run_interval_sweep(args: &Args) -> Result<()> {
    let cfg = host_base_cfg(args, 80)?;
    let intervals = sweep_intervals(args)?;
    let sink = EventSink::from_args(args)?;
    eprintln!(
        "interval sweep: moss re-anchor interval over {:?} vs bf16 anchor, {} steps, seed {}",
        intervals, cfg.steps, cfg.seed
    );
    let mut t = Table::new(
        "MOSS re-anchor interval sweep (host backend, shared seed/corpus)",
        &["mode", "interval", "first loss", "final loss", "gap vs bf16", "absmax calls"],
    );
    let mut labels: Vec<String> = Vec::new();
    let mut series: Vec<Vec<f64>> = Vec::new();
    let anchor = train_host_mode("ablate", &cfg, QuantMode::Bf16, &sink)?;
    let bf16_final = anchor.history.tail_loss(5);
    t.row(vec![
        "bf16".into(),
        "-".into(),
        f(anchor.history.losses.first().map_or(f64::NAN, |&(_, l)| l), 4),
        f(bf16_final, 4),
        "-".into(),
        "-".into(),
    ]);
    labels.push("bf16".into());
    series.push(anchor.history.loss_series());
    for &interval in &intervals {
        let mut c = cfg.clone();
        c.scaling = ScalingKind::Auto { interval };
        let tr = train_host_mode("ablate", &c, QuantMode::Moss, &sink)?;
        let final_loss = tr.history.tail_loss(5);
        t.row(vec![
            "moss".into(),
            format!("{interval}"),
            f(tr.history.losses.first().map_or(f64::NAN, |&(_, l)| l), 4),
            f(final_loss, 4),
            format!("{:+.4}", final_loss - bf16_final),
            tr.scaling_stats().absmax_calls.to_string(),
        ]);
        labels.push(format!("moss@{interval}"));
        series.push(tr.history.loss_series());
    }
    print!("{}", t.render());
    let curves: Vec<(&str, Vec<f64>)> = labels.iter().map(String::as_str).zip(series).collect();
    if let Some(out) = args.get("out") {
        std::fs::create_dir_all(out)?;
        for (name, content) in
            [("interval_sweep.csv", t.to_csv()), ("interval_sweep_losses.csv", curves_csv(&curves))]
        {
            let path = std::path::Path::new(out).join(name);
            std::fs::write(&path, content)?;
            eprintln!("wrote {}", path.display());
        }
    }
    if sink.active() {
        let lines = sink.close()?;
        eprintln!("events: wrote {lines} lines to {}", args.get_or("events", "?"));
    }
    Ok(())
}

/// `repro ablate`: train all four numerics modes on the host backend
/// over one shared seed/corpus and print the final-loss table — the
/// paper's central Fig. 5 / Table 2 comparison in one command, with
/// zero AOT artifacts. `--sweep-interval [N,N,..]` switches to the
/// re-anchor interval sweep instead of the mode ablation.
pub fn run_ablate_cli(args: &Args) -> Result<()> {
    if args.has("sweep-interval") || args.get("sweep-interval").is_some() {
        return run_interval_sweep(args);
    }
    let cfg = host_base_cfg(args, 80)?;
    let sink = EventSink::from_args(args)?;
    let spec = cfg.host;
    eprintln!(
        "mode ablation: model {} ({} heads), vocab {} dim {} ffn {} layers {} seq {} batch {} \
         x{} microbatches, {} steps, seed {}",
        spec.model.name(),
        spec.heads,
        spec.vocab,
        spec.dim,
        spec.ffn,
        spec.layers,
        spec.seq,
        spec.batch,
        spec.microbatches,
        cfg.steps,
        cfg.seed
    );
    let mut t = Table::new(
        "Mode ablation (host backend, shared seed/corpus)",
        &["mode", "first loss", "final loss", "gap vs bf16", "tokens/s"],
    );
    let mut curves: Vec<(&str, Vec<f64>)> = Vec::new();
    let mut bf16_final = f64::NAN;
    let mut fp8_finals: Vec<(QuantMode, f64)> = Vec::new();
    for mode in ABLATION_MODES {
        let tr = train_host_mode("ablate", &cfg, mode, &sink)?;
        let first = tr.history.losses.first().map_or(f64::NAN, |&(_, l)| l);
        let final_loss = tr.history.tail_loss(5);
        if mode == QuantMode::Bf16 {
            bf16_final = final_loss;
        } else {
            fp8_finals.push((mode, final_loss));
        }
        t.row(vec![
            mode.name().into(),
            f(first, 4),
            f(final_loss, 4),
            format!("{:+.4}", final_loss - bf16_final),
            f(tr.throughput.tokens_per_sec(), 0),
        ]);
        curves.push((mode.name(), tr.history.loss_series()));
    }
    print!("{}", t.render());
    let closest = fp8_finals
        .iter()
        .min_by(|a, b| {
            // total_cmp: a diverged (NaN-loss) mode sorts last instead
            // of panicking the report right after the table prints
            let (da, db) = ((a.1 - bf16_final).abs(), (b.1 - bf16_final).abs());
            da.total_cmp(&db)
        })
        .expect("three FP8 modes ran");
    println!(
        "closest FP8 mode to bf16: {} (|gap| {:.4})",
        closest.0.name(),
        (closest.1 - bf16_final).abs()
    );
    if let Some(out) = args.get("out") {
        std::fs::create_dir_all(out)?;
        let path = std::path::Path::new(out).join("ablate_losses.csv");
        std::fs::write(&path, curves_csv(&curves))?;
        eprintln!("wrote {}", path.display());
    }
    if sink.active() {
        let lines = sink.close()?;
        eprintln!("events: wrote {lines} lines to {}", args.get_or("events", "?"));
    }
    Ok(())
}

/// Fig 6 + Tables 3/11: fine-tune bf16/moss (+ jit-vs-auto for Tab 11)
/// and evaluate task accuracy.
pub fn run_finetune_report(args: &Args) -> Result<()> {
    let mut cfg = base_cfg(args, 150)?;
    cfg.data = DataKind::MathTasks;
    cfg.lr.peak = args.get_f64("lr", 1e-3)?; // small models need more than 5e-5
    cfg.probe_every = (cfg.steps / 16).max(1);
    let rt = Arc::new(Runtime::load(&cfg.artifact_dir())?);
    let n_eval = args.get_usize("eval-problems", 48)?;

    let mut t3 = Table::new(
        "Table 3 (measured, scaled-down) — fine-tuning on math tasks",
        &["mode", "samples/s", "final loss", "Mathematics", "GSM8K", "NumGLUE"],
    );
    let mut curves: Vec<(&str, Vec<f64>)> = Vec::new();
    let mut probes_from_moss = None;
    for mode in [QuantMode::Bf16, QuantMode::Moss] {
        let tr = train_mode(&rt, &cfg, mode)?;
        let sps = tr.throughput.tokens_per_sec() / rt.manifest.model.seq as f64;
        let mut row = vec![mode.name().to_string(), f(sps, 2), f(tr.history.tail_loss(20), 4)];
        for kind in TaskKind::ALL {
            let acc = crate::eval::eval_task_accuracy(&rt, &tr.state, kind, n_eval, cfg.seed)?;
            row.push(format!("{:.1}%", acc * 100.0));
        }
        t3.row(row);
        curves.push((mode.name(), tr.history.loss_series()));
        if mode == QuantMode::Moss {
            probes_from_moss = Some(tr.probes);
        }
    }
    let series: Vec<(&str, &[f64])> =
        curves.iter().map(|(n, v)| (*n, v.as_slice())).collect();
    super::emit_text(
        args,
        "fig6_finetune_loss",
        &multi_line_plot("Figure 6 — fine-tuning loss (scaled-down)", &series, 72, 16),
    )?;
    super::emit(args, "table3_finetune", &t3)?;

    // Table 11: JIT vs automatic scaling accuracy parity (moss mode).
    let mut t11 = Table::new(
        "Table 11 (measured, scaled-down) — JIT vs automatic scaling",
        &["scaling", "Mathematics", "GSM8K", "NumGLUE", "absmax calls"],
    );
    for scaling in [ScalingKind::Jit, ScalingKind::Auto { interval: 500 }] {
        let mut c = cfg.clone();
        c.mode = QuantMode::Moss;
        c.scaling = scaling;
        let mut tr = Trainer::new(rt.clone(), c)?;
        tr.run(cfg.steps)?;
        let mut row = vec![tr.scaler_name().to_string()];
        for kind in TaskKind::ALL {
            let acc = crate::eval::eval_task_accuracy(&rt, &tr.state, kind, n_eval, cfg.seed)?;
            row.push(format!("{:.1}%", acc * 100.0));
        }
        row.push(tr.scaling_stats().absmax_calls.to_string());
        t11.row(row);
    }
    super::emit(args, "table11_scaling_accuracy", &t11)?;

    // Table 7 on the real probes collected during the MOSS run.
    if let Some(probes) = probes_from_moss {
        for (metric, name) in
            [(Metric::Model, "model"), (Metric::Relative, "relative")]
        {
            if let Some(t7) = super::snr::table7_from_probes(&probes, metric) {
                super::emit(args, &format!("table7_real_probes_{name}"), &t7)?;
            }
        }
    }
    Ok(())
}

/// Fig 7: extended MOSS-only run demonstrating stability.
pub fn run_longrun_report(args: &Args) -> Result<()> {
    let mut cfg = base_cfg(args, 400)?;
    cfg.mode = QuantMode::Moss;
    let rt = Arc::new(Runtime::load(&cfg.artifact_dir())?);
    let mut tr = Trainer::new(rt.clone(), cfg.clone())?;
    tr.run(cfg.steps)?;
    let losses = tr.history.loss_series();
    super::emit_text(
        args,
        "fig7_long_run",
        &multi_line_plot("Figure 7 — extended MOSS FP8 training", &[("moss", &losses)], 72, 16),
    )?;
    // stability check: no NaN, downward trend
    anyhow::ensure!(losses.iter().all(|l| l.is_finite()), "loss diverged");
    Ok(())
}

/// Table 4: accuracy parity at two model sizes (uses tiny + small
/// configs as the 14B/32B stand-ins).
pub fn run_table4_report(args: &Args) -> Result<()> {
    let mut t4 = Table::new(
        "Table 4 (measured, scaled-down) — parity across model sizes",
        &["config", "precision", "Mathematics", "GSM8K", "NumGLUE"],
    );
    for conf in ["tiny", "small"] {
        let mut cfg = base_cfg(args, 150)?;
        cfg.artifact_config = conf.to_string();
        cfg.data = DataKind::MathTasks;
        cfg.lr.peak = 1e-3;
        if !cfg.artifact_dir().join("manifest.json").exists() {
            continue;
        }
        let rt = Arc::new(Runtime::load(&cfg.artifact_dir())?);
        for mode in [QuantMode::Bf16, QuantMode::Moss] {
            let tr = train_mode(&rt, &cfg, mode)?;
            let mut row = vec![conf.to_string(), mode.name().to_string()];
            for kind in TaskKind::ALL {
                let acc = crate::eval::eval_task_accuracy(&rt, &tr.state, kind, 48, cfg.seed)?;
                row.push(format!("{:.1}%", acc * 100.0));
            }
            t4.row(row);
        }
    }
    super::emit(args, "table4_size_parity", &t4)?;
    Ok(())
}
