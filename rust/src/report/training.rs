//! Training-dependent reports: Fig 5 (pretraining loss curves per mode),
//! Table 2 (measured throughput + PPL), Fig 6/Table 3 (fine-tuning),
//! Table 4 (accuracy parity across sizes), Fig 7 (long-run stability),
//! Table 7-from-probes. These run *real* training through the PJRT
//! runtime — durations scale with --steps / --config.

use std::sync::Arc;

use anyhow::Result;

use crate::cli::Args;
use crate::config::{DataKind, QuantMode, ScalingKind, TrainConfig};
use crate::coordinator::Trainer;
use crate::data::TaskKind;
use crate::eval::perplexity::eval_three_splits;
use crate::quant::snr::Metric;
use crate::runtime::Runtime;
use crate::util::plot::multi_line_plot;
use crate::util::table::{f, Table};

fn base_cfg(args: &Args, steps_default: u64) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    cfg.artifact_config = args.get_or("config", "small").to_string();
    cfg.steps = args.get_u64("steps", steps_default)?;
    cfg.lr.total_steps = cfg.steps;
    cfg.lr.warmup_steps = (cfg.steps / 10).max(5);
    cfg.lr.peak = args.get_f64("lr", 2e-4)?;
    cfg.log_every = args.get_u64("log-every", 50)?;
    cfg.seed = args.get_u64("seed", 0)?;
    Ok(cfg)
}

/// Train one mode to completion and return the trainer.
fn train_mode(rt: &Arc<Runtime>, cfg: &TrainConfig, mode: QuantMode) -> Result<Trainer> {
    let mut c = cfg.clone();
    c.mode = mode;
    if mode == QuantMode::Coat || mode == QuantMode::Bf16 {
        // these modes quantize weights JIT inside the graph (or not at
        // all); the injected scales are unused, skip absmax entirely
        c.scaling = ScalingKind::Auto { interval: u64::MAX };
    }
    let mut tr = Trainer::new(rt.clone(), c)?;
    tr.run(cfg.steps)?;
    Ok(tr)
}

/// Fig 5 + Table 2: pretraining loss curves and throughput/PPL table.
pub fn run_pretrain_report(args: &Args) -> Result<()> {
    let cfg = base_cfg(args, 120)?;
    let rt = Arc::new(Runtime::load(&cfg.artifact_dir())?);
    let modes = [QuantMode::Bf16, QuantMode::Coat, QuantMode::Moss];
    let mut curves: Vec<(&str, Vec<f64>)> = Vec::new();
    let mut t2 = Table::new(
        "Table 2 (measured, scaled-down) — pretraining on synthetic corpus",
        &["mode", "tokens/s (CPU)", "vs BF16", "final loss", "wikitext PPL", "c4 PPL", "pile PPL"],
    );
    let mut bf16_tps = 0f64;
    for mode in modes {
        let tr = train_mode(&rt, &cfg, mode)?;
        let tps = tr.throughput.tokens_per_sec();
        if mode == QuantMode::Bf16 {
            bf16_tps = tps;
        }
        let ppls = eval_three_splits(&rt, &tr.state, 4)?;
        t2.row(vec![
            mode.name().into(),
            f(tps, 0),
            format!("{:+.1}%", (tps / bf16_tps - 1.0) * 100.0),
            f(tr.history.tail_loss(20), 4),
            f(ppls[0].1, 2),
            f(ppls[1].1, 2),
            f(ppls[2].1, 2),
        ]);
        curves.push((mode.name(), tr.history.loss_series()));
    }
    let series: Vec<(&str, &[f64])> =
        curves.iter().map(|(n, v)| (*n, v.as_slice())).collect();
    let plot = multi_line_plot("Figure 5 — pretraining loss (scaled-down)", &series, 72, 16);
    super::emit_text(args, "fig5_pretrain_loss", &plot)?;
    // csv of the curves
    let mut csv = String::from("step,bf16,coat,moss\n");
    for i in 0..curves[0].1.len() {
        csv.push_str(&format!(
            "{},{},{},{}\n",
            i + 1,
            curves[0].1[i],
            curves[1].1.get(i).copied().unwrap_or(f64::NAN),
            curves[2].1.get(i).copied().unwrap_or(f64::NAN)
        ));
    }
    std::fs::write(super::results_dir(args).join("fig5_pretrain_loss.csv"), csv)?;
    super::emit(args, "table2_measured", &t2)?;
    Ok(())
}

/// Fig 6 + Tables 3/11: fine-tune bf16/moss (+ jit-vs-auto for Tab 11)
/// and evaluate task accuracy.
pub fn run_finetune_report(args: &Args) -> Result<()> {
    let mut cfg = base_cfg(args, 150)?;
    cfg.data = DataKind::MathTasks;
    cfg.lr.peak = args.get_f64("lr", 1e-3)?; // small models need more than 5e-5
    cfg.probe_every = (cfg.steps / 16).max(1);
    let rt = Arc::new(Runtime::load(&cfg.artifact_dir())?);
    let n_eval = args.get_usize("eval-problems", 48)?;

    let mut t3 = Table::new(
        "Table 3 (measured, scaled-down) — fine-tuning on math tasks",
        &["mode", "samples/s", "final loss", "Mathematics", "GSM8K", "NumGLUE"],
    );
    let mut curves: Vec<(&str, Vec<f64>)> = Vec::new();
    let mut probes_from_moss = None;
    for mode in [QuantMode::Bf16, QuantMode::Moss] {
        let tr = train_mode(&rt, &cfg, mode)?;
        let sps = tr.throughput.tokens_per_sec() / rt.manifest.model.seq as f64;
        let mut row = vec![mode.name().to_string(), f(sps, 2), f(tr.history.tail_loss(20), 4)];
        for kind in TaskKind::ALL {
            let acc = crate::eval::eval_task_accuracy(&rt, &tr.state, kind, n_eval, cfg.seed)?;
            row.push(format!("{:.1}%", acc * 100.0));
        }
        t3.row(row);
        curves.push((mode.name(), tr.history.loss_series()));
        if mode == QuantMode::Moss {
            probes_from_moss = Some(tr.probes);
        }
    }
    let series: Vec<(&str, &[f64])> =
        curves.iter().map(|(n, v)| (*n, v.as_slice())).collect();
    super::emit_text(
        args,
        "fig6_finetune_loss",
        &multi_line_plot("Figure 6 — fine-tuning loss (scaled-down)", &series, 72, 16),
    )?;
    super::emit(args, "table3_finetune", &t3)?;

    // Table 11: JIT vs automatic scaling accuracy parity (moss mode).
    let mut t11 = Table::new(
        "Table 11 (measured, scaled-down) — JIT vs automatic scaling",
        &["scaling", "Mathematics", "GSM8K", "NumGLUE", "absmax calls"],
    );
    for scaling in [ScalingKind::Jit, ScalingKind::Auto { interval: 500 }] {
        let mut c = cfg.clone();
        c.mode = QuantMode::Moss;
        c.scaling = scaling;
        let mut tr = Trainer::new(rt.clone(), c)?;
        tr.run(cfg.steps)?;
        let mut row = vec![tr.scaler_name().to_string()];
        for kind in TaskKind::ALL {
            let acc = crate::eval::eval_task_accuracy(&rt, &tr.state, kind, n_eval, cfg.seed)?;
            row.push(format!("{:.1}%", acc * 100.0));
        }
        row.push(tr.scaling_stats().absmax_calls.to_string());
        t11.row(row);
    }
    super::emit(args, "table11_scaling_accuracy", &t11)?;

    // Table 7 on the real probes collected during the MOSS run.
    if let Some(probes) = probes_from_moss {
        for (metric, name) in
            [(Metric::Model, "model"), (Metric::Relative, "relative")]
        {
            if let Some(t7) = super::snr::table7_from_probes(&probes, metric) {
                super::emit(args, &format!("table7_real_probes_{name}"), &t7)?;
            }
        }
    }
    Ok(())
}

/// Fig 7: extended MOSS-only run demonstrating stability.
pub fn run_longrun_report(args: &Args) -> Result<()> {
    let mut cfg = base_cfg(args, 400)?;
    cfg.mode = QuantMode::Moss;
    let rt = Arc::new(Runtime::load(&cfg.artifact_dir())?);
    let mut tr = Trainer::new(rt.clone(), cfg.clone())?;
    tr.run(cfg.steps)?;
    let losses = tr.history.loss_series();
    super::emit_text(
        args,
        "fig7_long_run",
        &multi_line_plot("Figure 7 — extended MOSS FP8 training", &[("moss", &losses)], 72, 16),
    )?;
    // stability check: no NaN, downward trend
    anyhow::ensure!(losses.iter().all(|l| l.is_finite()), "loss diverged");
    Ok(())
}

/// Table 4: accuracy parity at two model sizes (uses tiny + small
/// configs as the 14B/32B stand-ins).
pub fn run_table4_report(args: &Args) -> Result<()> {
    let mut t4 = Table::new(
        "Table 4 (measured, scaled-down) — parity across model sizes",
        &["config", "precision", "Mathematics", "GSM8K", "NumGLUE"],
    );
    for conf in ["tiny", "small"] {
        let mut cfg = base_cfg(args, 150)?;
        cfg.artifact_config = conf.to_string();
        cfg.data = DataKind::MathTasks;
        cfg.lr.peak = 1e-3;
        if !cfg.artifact_dir().join("manifest.json").exists() {
            continue;
        }
        let rt = Arc::new(Runtime::load(&cfg.artifact_dir())?);
        for mode in [QuantMode::Bf16, QuantMode::Moss] {
            let tr = train_mode(&rt, &cfg, mode)?;
            let mut row = vec![conf.to_string(), mode.name().to_string()];
            for kind in TaskKind::ALL {
                let acc = crate::eval::eval_task_accuracy(&rt, &tr.state, kind, 48, cfg.seed)?;
                row.push(format!("{:.1}%", acc * 100.0));
            }
            t4.row(row);
        }
    }
    super::emit(args, "table4_size_parity", &t4)?;
    Ok(())
}
