//! Minimal offline shim of the `xla` (xla-rs / xla_extension) bindings.
//!
//! The build container carries no PJRT/XLA native library, so this crate
//! provides the exact API surface the `moss` runtime layer compiles
//! against, split in two tiers:
//!
//! * **Fully functional** — [`Literal`] and [`ElementType`]: typed host
//!   tensors with shape/dtype checking, byte-exact round-tripping, and
//!   the constructors/accessors `runtime::literal` marshals through.
//!   Checkpointing, train-state plumbing and every host-side test work
//!   unchanged on these.
//! * **Stubbed** — [`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`HloModuleProto`], [`XlaComputation`]: program loading parses and
//!   retains the HLO text (so manifest/entry-layout validation runs for
//!   real), but [`PjRtLoadedExecutable::execute`] returns a descriptive
//!   [`Error`] — executing lowered programs requires the real
//!   `xla_extension` backend, which the artifact-gated integration tests
//!   already treat as optional.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring `xla::Error` (implements `std::error::Error`, so
/// `?` converts it into `anyhow::Error` at the call sites).
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    pub fn new<M: fmt::Display>(message: M) -> Error {
        Error { message: message.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the moss runtime traffics in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    S8,
    U32,
}

impl ElementType {
    pub fn primitive_type(&self) -> PrimitiveType {
        match self {
            ElementType::F32 => PrimitiveType::F32,
            ElementType::S32 => PrimitiveType::S32,
            ElementType::S8 => PrimitiveType::S8,
            ElementType::U32 => PrimitiveType::U32,
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            ElementType::S8 => 1,
            _ => 4,
        }
    }
}

/// Wire-level dtype tags (subset of the XLA PrimitiveType proto enum).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
    S8,
    U32,
}

impl PrimitiveType {
    pub fn element_type(&self) -> ElementType {
        match self {
            PrimitiveType::F32 => ElementType::F32,
            PrimitiveType::S32 => ElementType::S32,
            PrimitiveType::S8 => ElementType::S8,
            PrimitiveType::U32 => ElementType::U32,
        }
    }
}

/// Host dtypes a [`Literal`] can be built from / downloaded into.
pub trait NativeType: Copy {
    const ELEMENT: ElementType;

    fn to_le_bytes_vec(values: &[Self]) -> Vec<u8>;
    fn from_le_bytes_slice(bytes: &[u8]) -> Vec<Self>;
}

macro_rules! native_type {
    ($t:ty, $elem:expr, $width:expr) => {
        impl NativeType for $t {
            const ELEMENT: ElementType = $elem;

            fn to_le_bytes_vec(values: &[Self]) -> Vec<u8> {
                let mut out = Vec::with_capacity(values.len() * $width);
                for v in values {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out
            }

            fn from_le_bytes_slice(bytes: &[u8]) -> Vec<Self> {
                bytes
                    .chunks_exact($width)
                    .map(|c| {
                        let mut a = [0u8; $width];
                        a.copy_from_slice(c);
                        <$t>::from_le_bytes(a)
                    })
                    .collect()
            }
        }
    };
}

native_type!(f32, ElementType::F32, 4);
native_type!(i32, ElementType::S32, 4);
native_type!(u32, ElementType::U32, 4);
native_type!(i8, ElementType::S8, 1);

/// A typed host tensor: dtype + dims + little-endian payload bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    /// Build a literal from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = dims.iter().product::<usize>() * ty.size_bytes();
        if data.len() != want {
            return Err(Error::new(format!(
                "literal payload is {} bytes, shape {dims:?} of {ty:?} wants {want}",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    /// Zero-filled literal of the given shape.
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let ty = ty.element_type();
        let bytes = dims.iter().product::<usize>() * ty.size_bytes();
        Literal { ty, dims: dims.to_vec(), data: vec![0u8; bytes] }
    }

    /// Rank-0 literal holding one value.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { ty: T::ELEMENT, dims: Vec::new(), data: T::to_le_bytes_vec(&[v]) }
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Download the payload as a typed vector (dtype-checked).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::ELEMENT {
            return Err(Error::new(format!(
                "literal holds {:?}, requested {:?}",
                self.ty,
                T::ELEMENT
            )));
        }
        Ok(T::from_le_bytes_slice(&self.data))
    }

    /// First element of the flattened payload (dtype-checked).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error::new("literal is empty"))
    }

    /// Tuple decomposition — the shim never materializes tuple literals
    /// (execution is stubbed), so this is always an error.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::new("not a tuple literal (offline shim)"))
    }
}

/// Parsed-but-uncompiled HLO module (retains the program text).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Read an HLO text file; validates the `HloModule` header like the
    /// real parser would before handing the module to the compiler.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading {path}: {e}")))?;
        if !text.starts_with("HloModule") {
            return Err(Error::new(format!("{path} is not an HLO text file")));
        }
        Ok(HloModuleProto { text })
    }
}

/// A computation handle wrapping a module proto.
pub struct XlaComputation {
    pub text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }
}

/// PJRT client stub. Construction succeeds so manifest loading and
/// program-spec validation run; only execution is unavailable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable)
    }
}

/// Device buffer stub (never produced by the stubbed execute path).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new("no device buffers in the offline shim"))
    }
}

/// Loaded-executable stub: execution needs the real PJRT backend.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(
            "program execution is unavailable in the offline xla shim; \
             build against the real xla_extension backend to run AOT artifacts",
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_literal_roundtrip() {
        let data = [1.5f32, -2.0, 0.0, 3.25];
        let bytes = f32::to_le_bytes_vec(&data);
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes).unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let lit = Literal::scalar(7i32);
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn shape_payload_mismatch_rejected() {
        let r = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 8]);
        assert!(r.is_err());
    }

    #[test]
    fn zeros_literal() {
        let lit = Literal::create_from_shape(PrimitiveType::S8, &[5]);
        assert_eq!(lit.to_vec::<i8>().unwrap(), vec![0i8; 5]);
    }

    #[test]
    fn execute_is_a_clear_error() {
        let exe = PjRtLoadedExecutable;
        let args: Vec<Literal> = vec![];
        let err = exe.execute(&args).unwrap_err();
        assert!(err.to_string().contains("offline"));
    }
}
