//! Minimal offline shim of the `anyhow` crate.
//!
//! The build container has no crates.io access, so this vendored crate
//! re-implements the small API surface the workspace actually uses:
//!
//! * [`Error`] — a string-backed error with a context chain,
//! * [`Result`] — `std::result::Result` with `Error` as the default error,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`: that is what permits the blanket
//! `From<E: std::error::Error>` conversion powering `?` without colliding
//! with the reflexive `From<Error> for Error` from core.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error carrying a chain of context messages.
///
/// `Display` prints the outermost message; the alternate form (`{:#}`)
/// prints the whole chain outermost-first, separated by `": "`, matching
/// how the real anyhow renders `{:#}`.
pub struct Error {
    /// Context chain, outermost message first (index 0 is what `Display`
    /// shows; the root cause is last).
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Push a new outermost context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message plus a "Caused by" trail.
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding context to fallible values.
pub trait Context<T>: Sized {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing file"));
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e: Result<()> = Err(io_err()).context("opening manifest");
        let e = e.unwrap_err();
        assert_eq!(format!("{e}"), "opening manifest");
        let full = format!("{e:#}");
        assert!(full.starts_with("opening manifest: "), "{full}");
        assert!(full.contains("missing file"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
        let v: Option<u32> = Some(7);
        assert_eq!(v.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
        assert!(f(101).unwrap_err().to_string().contains("too big"));
        let e = anyhow!("code {}", 42);
        assert_eq!(e.to_string(), "code 42");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
